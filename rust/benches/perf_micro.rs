//! Performance microbenches for the §Perf pass: per-layer hot paths.
//!
//!  - backend.step.*       train_step latency per spec (L3 view)
//!  - backend.overhead     smallest eval round-trip (framework tax)
//!  - data.batch.*         batch assembly throughput (host pipeline)
//!  - tensor.*             host-side measurement ops (sparsity probes)
//!  - native.matmul.*      the threaded native kernels: dense (nn/nt/tn)
//!                         vs masked block-sparse vs packed BSR at
//!                         50/75/90% block sparsity — the §4 inference
//!                         claim, measured (`benches/infer_serve.rs` is
//!                         the full panel) — plus the attention-projection
//!                         block-GEMM at the t3 vit_t shape. Every kernel
//!                         is benched twice: `.scalar` pins the reference
//!                         loops, and `.dispatched` runs whatever
//!                         `simd::dispatched()` resolves to (AVX2/NEON
//!                         when available, overridable via
//!                         `BS_NATIVE_SIMD`).
//!  - native.layernorm.*   the transformer LayerNorm sweep, forward and
//!                         backward, scalar vs dispatched like the matmuls
//!
//! Specs the active backend cannot run are skipped, not failed.
//!
//! `--json <path>` additionally writes the stats as one JSON object per
//! kernel (mean/p50/p95 ms + iters) plus a root `simd` label and a `gate`
//! object with the scalar→dispatched geomean speedup over the dense
//! matmul trio, e.g.
//! `cargo bench --bench perf_micro -- --json BENCH_native.json`, giving
//! future PRs a machine-readable perf trajectory to diff against.

use std::collections::BTreeMap;

use blocksparse::backend::native::linalg;
use blocksparse::backend::native::simd::{self, SimdKind};
use blocksparse::backend::Backend;
use blocksparse::bench::{json_arg, quick_bench, BenchStats, TableWriter};
use blocksparse::coordinator::dataset_for;
use blocksparse::data::{assemble_batch, Batcher};
use blocksparse::infer;
use blocksparse::tensor::Tensor;
use blocksparse::util::json::Json;
use blocksparse::util::rng::Rng;

fn write_json(
    path: &str,
    backend: &str,
    simd_label: &str,
    matmul_geomean: f64,
    stats: &[BenchStats],
) -> anyhow::Result<()> {
    let mut benches = BTreeMap::new();
    for s in stats {
        let mut o = BTreeMap::new();
        o.insert("mean_ms".to_string(), Json::num_or_null(s.mean_ns / 1e6));
        o.insert("p50_ms".to_string(), Json::num_or_null(s.p50_ns / 1e6));
        o.insert("p95_ms".to_string(), Json::num_or_null(s.p95_ns / 1e6));
        o.insert("iters".to_string(), Json::Num(s.iters as f64));
        benches.insert(s.name.clone(), Json::Obj(o));
    }
    let mut gate = BTreeMap::new();
    gate.insert(
        "matmul_geomean_speedup".to_string(),
        Json::num_or_null(matmul_geomean),
    );
    gate.insert("min_geomean_when_simd".to_string(), Json::Num(1.5));
    let mut root = BTreeMap::new();
    root.insert("backend".to_string(), Json::Str(backend.to_string()));
    root.insert("simd".to_string(), Json::Str(simd_label.to_string()));
    root.insert("gate".to_string(), Json::Obj(gate));
    root.insert("benches".to_string(), Json::Obj(benches));
    std::fs::write(path, Json::Obj(root).to_string_pretty())?;
    println!("wrote {path} ({} kernels)", stats.len());
    Ok(())
}

/// Bench `run` under the pinned scalar kind and under the dispatched kind,
/// pushing both (`<name>.scalar`, `<name>.dispatched`) onto `stats`, and
/// return the scalar→dispatched mean-latency speedup. On scalar-only
/// hosts both variants run the same loops and the speedup sits at ~1.0.
fn bench_pair<F: FnMut(SimdKind)>(
    stats: &mut Vec<BenchStats>,
    name: &str,
    kind: SimdKind,
    mut run: F,
) -> f64 {
    let scalar = quick_bench(&format!("{name}.scalar"), || run(SimdKind::Scalar));
    let disp = quick_bench(&format!("{name}.dispatched"), || run(kind));
    let speedup = scalar.mean_ns / disp.mean_ns;
    println!(
        "{name}: scalar {:.3} ms, {} {:.3} ms ({speedup:.2}x)",
        scalar.mean_ns / 1e6,
        kind.label(),
        disp.mean_ns / 1e6
    );
    stats.push(scalar);
    stats.push(disp);
    speedup
}

fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let be = blocksparse::backend::open_default()?;
    let mut stats = Vec::new();

    // ---- backend: one train step per spec family ------------------------
    for spec_key in ["t1_kpd_b2x2", "t1_gl_b2x2", "t1_rigl_b2x2",
                     "t2_kpd_16x8_8x4_4x2", "t3_vit_t_kpd", "it_lm_kpd"] {
        let Ok(spec) = be.spec(spec_key) else {
            println!("SKIP backend.step.{spec_key}: not available on '{}'", be.name());
            continue;
        };
        let spec = spec.clone();
        let (train, _) = dataset_for(&spec, 7, spec.batch * 2, spec.batch)?;
        let idx: Vec<usize> = (0..spec.batch).collect();
        let batch = assemble_batch(&train, &idx)?;
        let mut state = be.init_state(spec_key, 0)?;
        let hyper: Vec<f32> = spec.hyper.iter().map(|h| match h.as_str() {
            "lr" => 0.05,
            _ => 0.01,
        }).collect();
        stats.push(quick_bench(&format!("backend.step.{spec_key}"), || {
            be.train_step(&mut state, &batch.x, &batch.y, &hyper).expect("step");
        }));
    }

    // ---- framework overhead: smallest eval we have ----------------------
    if let Ok(spec) = be.spec("qs_kpd") {
        let spec = spec.clone();
        let (train, _) = dataset_for(&spec, 7, spec.batch * 2, spec.batch)?;
        let idx: Vec<usize> = (0..spec.batch).collect();
        let batch = assemble_batch(&train, &idx)?;
        let state = be.init_state("qs_kpd", 0)?;
        stats.push(quick_bench("backend.overhead.eval_qs", || {
            be.eval_step(&state, &batch.x, &batch.y).expect("eval");
        }));
    } else {
        println!("SKIP backend.overhead.eval_qs: not available on '{}'", be.name());
    }

    // ---- data pipeline ---------------------------------------------------
    if let Ok(spec) = be.spec("t1_kpd_b2x2") {
        let spec = spec.clone();
        let (train, _) = dataset_for(&spec, 7, 8192, 128)?;
        let mut b = Batcher::new(&train, 128, 1, true);
        stats.push(quick_bench("data.batch.mnist128", || {
            let _ = b.next_batch().expect("batch");
        }));
    } else {
        println!("SKIP data.batch.mnist128: not available on '{}'", be.name());
    }

    // ---- host tensor probes ----------------------------------------------
    {
        let mut rng = Rng::new(3);
        let w = Tensor::from_fn(&[120, 400], |_| rng.normal());
        stats.push(quick_bench("tensor.block_fro_120x400", || {
            std::hint::black_box(w.block_fro_norms(8, 16).unwrap());
        }));
        let s = Tensor::from_fn(&[15, 25], |_| rng.normal());
        let a = Tensor::from_fn(&[5, 15, 25], |_| rng.normal());
        let bt = Tensor::from_fn(&[5, 8, 16], |_| rng.normal());
        stats.push(quick_bench("tensor.kpd_reconstruct_120x400_r5", || {
            std::hint::black_box(Tensor::kpd_reconstruct(&s, &a, &bt).unwrap());
        }));
    }

    // ---- native kernels: dense vs block-sparse vs packed BSR --------------
    // The inference trajectory: the masked training matmul and the packed
    // BSR serving kernel against the dense path at 50/75/90% block
    // sparsity (the zeroed-block fraction; occupancy is the complement).
    // Each kernel runs twice — pinned-scalar and dispatched — so the JSON
    // records both the SIMD win and a drift baseline for scalar hosts.
    let kind = simd::dispatched();
    println!("SIMD dispatch: {}", kind.label());
    let mut dense_speedups: Vec<f64> = Vec::new();
    {
        let mut rng = Rng::new(4);
        let (nb, m, n, m2, n2) = (64usize, 120usize, 400usize, 8usize, 16usize);
        let x: Vec<f32> = (0..nb * n).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        // nt — the forward X·Wᵀ layout
        dense_speedups.push(bench_pair(
            &mut stats,
            "native.matmul.dense_64x400x120",
            kind,
            |k| {
                std::hint::black_box(linalg::matmul_nt_with(k, &x, &w, nb, n, m));
            },
        ));
        // nn — same macro shape against a pre-transposed W (the dX layout)
        let mut wt = vec![0.0f32; n * m];
        for i in 0..m {
            for j in 0..n {
                wt[j * m + i] = w[i * n + j];
            }
        }
        dense_speedups.push(bench_pair(
            &mut stats,
            "native.matmul.nn_64x400x120",
            kind,
            |k| {
                std::hint::black_box(linalg::matmul_nn_with(k, &x, &wt, nb, n, m));
            },
        ));
        // tn — dW = dZᵀ·X (the gradient layout)
        let dz: Vec<f32> = (0..nb * m).map(|_| rng.normal()).collect();
        dense_speedups.push(bench_pair(
            &mut stats,
            "native.matmul.tn_120x64x400",
            kind,
            |k| {
                std::hint::black_box(linalg::matmul_tn_with(k, &dz, &x, nb, m, n));
            },
        ));
        for sparsity in [0.50f64, 0.75, 0.90] {
            let (wm, mask) =
                infer::synth_block_sparse_weights(&mut rng, m, n, m2, n2, 1.0 - sparsity);
            let tag = (sparsity * 100.0).round() as u32;
            bench_pair(
                &mut stats,
                &format!("native.matmul.block_sparse{tag}"),
                kind,
                |k| {
                    std::hint::black_box(
                        linalg::block_sparse_matmul_nt_with(k, &x, &wm, &mask, nb, m, n, m2, n2)
                            .expect("block-sparse shapes"),
                    );
                },
            );
            let layer = infer::BsrLayer::from_dense("fc", &wm, m, n, m2, n2)?;
            bench_pair(&mut stats, &format!("native.matmul.bsr{tag}"), kind, |k| {
                std::hint::black_box(
                    infer::bsr::bsr_forward_with(k, &x, nb, &layer, false).expect("bsr shapes"),
                );
            });
        }
    }

    // ---- transformer hot paths --------------------------------------------
    // The two kernels the t3_* family adds to the per-step profile: the
    // attention-projection block-GEMM (every q/k/v/o projection is a
    // (batch·seq)×d × d×d matmul over a 4×4 block mask — vit_t shape:
    // 16 sequences of 16 tokens at d_model 64, half the blocks zeroed)
    // and the LayerNorm sweep that runs twice per encoder block.
    {
        let mut rng = Rng::new(5);
        let (rows, d, m2, n2) = (256usize, 64usize, 4usize, 4usize);
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let (wm, mask) = infer::synth_block_sparse_weights(&mut rng, d, d, m2, n2, 0.5);
        bench_pair(&mut stats, "native.matmul.attnproj_256x64x64_b4x4", kind, |k| {
            std::hint::black_box(
                linalg::block_sparse_matmul_nt_with(k, &x, &wm, &mask, rows, d, d, m2, n2)
                    .expect("attnproj shapes"),
            );
        });
        let g: Vec<f32> = (0..d).map(|_| 1.0 + 0.1 * rng.normal()).collect();
        let b: Vec<f32> = (0..d).map(|_| 0.1 * rng.normal()).collect();
        bench_pair(&mut stats, "native.layernorm.fwd_256x64", kind, |k| {
            std::hint::black_box(linalg::layernorm_with(k, &x, &g, &b, rows, d));
        });
        let (_, xhat, rstd) = linalg::layernorm(&x, &g, &b, rows, d);
        let dy: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        bench_pair(&mut stats, "native.layernorm.bwd_256x64", kind, |k| {
            std::hint::black_box(linalg::layernorm_backward_with(
                k, &dy, &xhat, &rstd, &g, rows, d,
            ));
        });
    }
    let matmul_geo = geomean(&dense_speedups);
    println!(
        "dense matmul geomean speedup (scalar → {}): {matmul_geo:.2}x",
        kind.label()
    );

    let mut t = TableWriter::new("perf microbenches", &["bench", "mean ms", "p50 ms", "p95 ms", "/s"]);
    for s in &stats {
        t.row(vec![
            s.name.clone(),
            format!("{:.3}", s.mean_ns / 1e6),
            format!("{:.3}", s.p50_ns / 1e6),
            format!("{:.3}", s.p95_ns / 1e6),
            format!("{:.1}", s.throughput_per_sec()),
        ]);
    }
    t.print();
    if let Some(path) = json_arg(&args, "BENCH_native.json") {
        write_json(&path, &be.name(), kind.label(), matmul_geo, &stats)?;
    }
    Ok(())
}
