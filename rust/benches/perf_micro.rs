//! Performance microbenches for the §Perf pass: per-layer hot paths.
//!
//!  - backend.step.*       train_step latency per spec (L3 view)
//!  - backend.overhead     smallest eval round-trip (framework tax)
//!  - data.batch.*         batch assembly throughput (host pipeline)
//!  - tensor.*             host-side measurement ops (sparsity probes)
//!  - native.matmul.*      the threaded native kernels (dense vs block-
//!                         sparse — the §4 inference claim, measured)
//!
//! Specs the active backend cannot run are skipped, not failed.
//!
//! `--json <path>` additionally writes the stats as one JSON object per
//! kernel (mean/p50/p95 ms + iters), e.g.
//! `cargo bench --bench perf_micro -- --json BENCH_native.json`, giving
//! future PRs a machine-readable perf trajectory to diff against.

use std::collections::BTreeMap;

use blocksparse::backend::native::linalg;
use blocksparse::backend::Backend;
use blocksparse::bench::{quick_bench, BenchStats, TableWriter};
use blocksparse::coordinator::dataset_for;
use blocksparse::data::{assemble_batch, Batcher};
use blocksparse::tensor::Tensor;
use blocksparse::util::json::Json;
use blocksparse::util::rng::Rng;

/// `--json <path>` / `--json=<path>` from the post-`--` bench args.
fn json_path(args: &[String]) -> Option<String> {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--json" {
            return it.next().cloned().or_else(|| Some("BENCH_native.json".to_string()));
        }
        if let Some(p) = a.strip_prefix("--json=") {
            return Some(p.to_string());
        }
    }
    None
}

fn write_json(path: &str, backend: &str, stats: &[BenchStats]) -> anyhow::Result<()> {
    let mut benches = BTreeMap::new();
    for s in stats {
        let mut o = BTreeMap::new();
        o.insert("mean_ms".to_string(), Json::Num(s.mean_ns / 1e6));
        o.insert("p50_ms".to_string(), Json::Num(s.p50_ns / 1e6));
        o.insert("p95_ms".to_string(), Json::Num(s.p95_ns / 1e6));
        o.insert("iters".to_string(), Json::Num(s.iters as f64));
        benches.insert(s.name.clone(), Json::Obj(o));
    }
    let mut root = BTreeMap::new();
    root.insert("backend".to_string(), Json::Str(backend.to_string()));
    root.insert("benches".to_string(), Json::Obj(benches));
    std::fs::write(path, Json::Obj(root).to_string_pretty())?;
    println!("wrote {path} ({} kernels)", stats.len());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let be = blocksparse::backend::open_default()?;
    let mut stats = Vec::new();

    // ---- backend: one train step per spec family ------------------------
    for spec_key in ["t1_kpd_b2x2", "t1_gl_b2x2", "t1_rigl_b2x2",
                     "t2_kpd_16x8_8x4_4x2", "t3_vit_t_kpd", "it_lm_kpd"] {
        let Ok(spec) = be.spec(spec_key) else {
            println!("SKIP backend.step.{spec_key}: not available on '{}'", be.name());
            continue;
        };
        let spec = spec.clone();
        let (train, _) = dataset_for(&spec, 7, spec.batch * 2, spec.batch)?;
        let idx: Vec<usize> = (0..spec.batch).collect();
        let batch = assemble_batch(&train, &idx)?;
        let mut state = be.init_state(spec_key, 0)?;
        let hyper: Vec<f32> = spec.hyper.iter().map(|h| match h.as_str() {
            "lr" => 0.05,
            _ => 0.01,
        }).collect();
        stats.push(quick_bench(&format!("backend.step.{spec_key}"), || {
            be.train_step(&mut state, &batch.x, &batch.y, &hyper).expect("step");
        }));
    }

    // ---- framework overhead: smallest eval we have ----------------------
    if let Ok(spec) = be.spec("qs_kpd") {
        let spec = spec.clone();
        let (train, _) = dataset_for(&spec, 7, spec.batch * 2, spec.batch)?;
        let idx: Vec<usize> = (0..spec.batch).collect();
        let batch = assemble_batch(&train, &idx)?;
        let state = be.init_state("qs_kpd", 0)?;
        stats.push(quick_bench("backend.overhead.eval_qs", || {
            be.eval_step(&state, &batch.x, &batch.y).expect("eval");
        }));
    } else {
        println!("SKIP backend.overhead.eval_qs: not available on '{}'", be.name());
    }

    // ---- data pipeline ---------------------------------------------------
    if let Ok(spec) = be.spec("t1_kpd_b2x2") {
        let spec = spec.clone();
        let (train, _) = dataset_for(&spec, 7, 8192, 128)?;
        let mut b = Batcher::new(&train, 128, 1, true);
        stats.push(quick_bench("data.batch.mnist128", || {
            let _ = b.next_batch().expect("batch");
        }));
    } else {
        println!("SKIP data.batch.mnist128: not available on '{}'", be.name());
    }

    // ---- host tensor probes ----------------------------------------------
    {
        let mut rng = Rng::new(3);
        let w = Tensor::from_fn(&[120, 400], |_| rng.normal());
        stats.push(quick_bench("tensor.block_fro_120x400", || {
            std::hint::black_box(w.block_fro_norms(8, 16).unwrap());
        }));
        let s = Tensor::from_fn(&[15, 25], |_| rng.normal());
        let a = Tensor::from_fn(&[5, 15, 25], |_| rng.normal());
        let bt = Tensor::from_fn(&[5, 8, 16], |_| rng.normal());
        stats.push(quick_bench("tensor.kpd_reconstruct_120x400_r5", || {
            std::hint::black_box(Tensor::kpd_reconstruct(&s, &a, &bt).unwrap());
        }));
    }

    // ---- native kernels: dense vs block-sparse matmul ---------------------
    {
        let mut rng = Rng::new(4);
        let (nb, m, n, m2, n2) = (64usize, 120usize, 400usize, 8usize, 16usize);
        let (m1, n1) = (m / m2, n / n2);
        let x: Vec<f32> = (0..nb * n).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        // 50% block mask (checkerboard)
        let mask: Vec<f32> = (0..m1 * n1)
            .map(|i| if (i / n1 + i % n1) % 2 == 0 { 0.0 } else { 1.0 })
            .collect();
        let dense = quick_bench("native.matmul.dense_64x400x120", || {
            std::hint::black_box(linalg::matmul_nt(&x, &w, nb, n, m));
        });
        let sparse = quick_bench("native.matmul.block_sparse50", || {
            std::hint::black_box(linalg::block_sparse_matmul_nt(
                &x, &w, &mask, nb, m, n, m2, n2,
            ));
        });
        println!(
            "block-sparse/dense inference speedup: {:.2}x (flops model predicts ~2x at 50%)",
            dense.mean_ns / sparse.mean_ns
        );
        stats.push(dense);
        stats.push(sparse);
    }

    let mut t = TableWriter::new("perf microbenches", &["bench", "mean ms", "p50 ms", "p95 ms", "/s"]);
    for s in &stats {
        t.row(vec![
            s.name.clone(),
            format!("{:.3}", s.mean_ns / 1e6),
            format!("{:.3}", s.p50_ns / 1e6),
            format!("{:.3}", s.p95_ns / 1e6),
            format!("{:.1}", s.throughput_per_sec()),
        ]);
    }
    t.print();
    if let Some(path) = json_path(&args) {
        write_json(&path, &be.name(), &stats)?;
    }
    Ok(())
}
