//! Regenerates **Table 3**: transformers with 4×4 blocks, natively.
//!
//! Model substitution (DESIGN.md §5): paper-scale ViT-t/ViT-b/Swin-t on
//! CIFAR-100 do not train on this CPU testbed; the native backend runs
//! width/depth-scaled causal encoders (`t3_*` specs on the Markov LM
//! corpus — same pre-LN attention + FFN block structure, every projection
//! block-sparsified at 4×4) and the bench verifies the paper's *shape*:
//! Ours cuts training params/FLOPs by a large factor (97% for ViT-t in
//! the paper) at accuracy ≥ the group-LASSO baselines, while blockwise
//! RigL loses accuracy on transformers. Each row's per-projection
//! sparsity breakdown prints under the table, like table2's.
//!
//! Per-model step budgets keep the full bench within a CPU budget; raise
//! BS_STEPS for the committed EXPERIMENTS.md numbers.

use blocksparse::bench::driver::{self, BenchEnv, ROW_HEADERS};
use blocksparse::bench::TableWriter;

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let be = blocksparse::backend::open_default()?;
    let mut table = TableWriter::new(
        "Table 3 — transformers on synthetic-CIFAR-100, 4×4 blocks (paper: Table 3)",
        &ROW_HEADERS,
    );

    // (tag, label, default steps, seeds): vit_b-proxy steps are costly
    let models: &[(&str, &str, usize, usize)] = &[
        ("vit_t", "ViT-t (scaled)", 200, 1),
        ("vit_b", "ViT-b (scaled)", 60, 1),
        ("swin_t", "Swin-t (scaled)", 100, 1),
    ];
    let paper: &[(&str, &str, &str)] = &[
        ("vit_t", "dense", "64.32 ± 1.92"),
        ("vit_t", "group_lasso", "60.41 ± 4.24"),
        ("vit_t", "elastic_gl", "61.92 ± 3.01"),
        ("vit_t", "rigl_block", "49.56 ± 0.48"),
        ("vit_t", "kpd", "62.99 ± 0.73"),
        ("vit_b", "dense", "71.34 ± 0.42"),
        ("vit_b", "group_lasso", "68.41 ± 1.24"),
        ("vit_b", "elastic_gl", "66.95 ± 2.17"),
        ("vit_b", "kpd", "69.82 ± 0.22"),
        ("swin_t", "dense", "81.44 ± 0.05"),
        ("swin_t", "group_lasso", "75.87 ± 2.17"),
        ("swin_t", "elastic_gl", "76.34 ± 0.82"),
        ("swin_t", "rigl_block", "60.30 ± 0.22"),
        ("swin_t", "kpd", "77.54 ± 0.42"),
    ];

    let mut breakdowns: Vec<(String, String)> = Vec::new();
    for (tag, label, steps, seeds) in models {
        let env = BenchEnv::from_env(*steps, *seeds, 4096, 1024);
        for method in ["dense", "gl", "egl", "rigl", "kpd"] {
            let spec = format!("t3_{tag}_{method}");
            // the one intentional gap: the paper's Table 3 itself has no
            // ViT-b RigL row, so neither do we (the CI gate greps for
            // unavailable-spec SKIPs only, not this one)
            if *tag == "vit_b" && method == "rigl" {
                println!("omitting {spec}: the paper's Table 3 has no ViT-b RigL row");
                continue;
            }
            // every unavailable spec gets an explicit per-spec reason, so
            // a backend without the family is visible instead of silently
            // shrinking the table
            let Some(res) = driver::run_row_or_skip(be.as_ref(), &env, &spec)? else {
                continue;
            };
            driver::record_row("table3", label, &res)?;
            let pref = paper
                .iter()
                .find(|(t, m, _)| t == tag && *m == res.method)
                .map(|(_, _, v)| *v);
            table.row(driver::cells(label, &res.method, &res, pref));
            if let Some(b) = driver::layer_breakdown(&res) {
                breakdowns.push((spec, b));
            }
        }
    }
    table.print();
    if !breakdowns.is_empty() {
        println!("per-layer sparsity:");
        for (spec, b) in &breakdowns {
            println!("  {spec:<22} {b}");
        }
    }
    println!("rows emitted: {}", table.rows.len());
    println!("shape checks:");
    println!("  - Ours train-params ≪ dense for every model (paper: 97% cut, ViT-t)");
    println!("  - RigL accuracy collapses on transformers (paper: 49.6 vs 64.3)");
    Ok(())
}
