//! Regenerates **Table 3**: transformers on (synthetic) CIFAR-100 with
//! 4×4 blocks.
//!
//! Model substitution (DESIGN.md §5): paper-scale ViT-t/ViT-b/Swin-t do
//! not train on this CPU testbed; we use width/depth-scaled encoders
//! (vit_micro / vit_small / swin_proxy) with the same architecture family
//! and verify the paper's *shape*: Ours cuts training params/FLOPs by a
//! large factor (97% for ViT-t in the paper) at accuracy ≥ the group-LASSO
//! baselines, while blockwise RigL loses accuracy on transformers.
//!
//! Per-model step budgets keep the full bench within a CPU budget; raise
//! BS_STEPS for the committed EXPERIMENTS.md numbers.

use blocksparse::backend::Backend;
use blocksparse::bench::driver::{self, BenchEnv, ROW_HEADERS};
use blocksparse::bench::TableWriter;

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let be = blocksparse::backend::open_default()?;
    let mut table = TableWriter::new(
        "Table 3 — transformers on synthetic-CIFAR-100, 4×4 blocks (paper: Table 3)",
        &ROW_HEADERS,
    );

    // (tag, label, default steps, seeds): vit_b-proxy steps are costly
    let models: &[(&str, &str, usize, usize)] = &[
        ("vit_t", "ViT-t (scaled)", 200, 1),
        ("vit_b", "ViT-b (scaled)", 60, 1),
        ("swin_t", "Swin-t (scaled)", 100, 1),
    ];
    let paper: &[(&str, &str, &str)] = &[
        ("vit_t", "dense", "64.32 ± 1.92"),
        ("vit_t", "group_lasso", "60.41 ± 4.24"),
        ("vit_t", "elastic_gl", "61.92 ± 3.01"),
        ("vit_t", "rigl_block", "49.56 ± 0.48"),
        ("vit_t", "kpd", "62.99 ± 0.73"),
        ("vit_b", "dense", "71.34 ± 0.42"),
        ("vit_b", "group_lasso", "68.41 ± 1.24"),
        ("vit_b", "elastic_gl", "66.95 ± 2.17"),
        ("vit_b", "kpd", "69.82 ± 0.22"),
        ("swin_t", "dense", "81.44 ± 0.05"),
        ("swin_t", "group_lasso", "75.87 ± 2.17"),
        ("swin_t", "elastic_gl", "76.34 ± 0.82"),
        ("swin_t", "rigl_block", "60.30 ± 0.22"),
        ("swin_t", "kpd", "77.54 ± 0.42"),
    ];

    for (tag, label, steps, seeds) in models {
        let env = BenchEnv::from_env(*steps, *seeds, 4096, 1024);
        for method in ["dense", "gl", "egl", "rigl", "kpd"] {
            let spec = format!("t3_{tag}_{method}");
            // every unavailable spec gets an explicit per-spec reason, so
            // the unimplemented transformer family is visible instead of
            // silently shrinking the table
            if *tag == "vit_b" && method == "rigl" {
                println!("SKIP {spec}: the paper's Table 3 has no ViT-b RigL row");
                continue;
            }
            if be.spec(&spec).is_err() {
                println!(
                    "SKIP {spec}: transformer family not implemented on backend '{}' \
                     (needs a --features pjrt build with AOT vit/swin artifacts)",
                    be.name()
                );
                continue;
            }
            let res = driver::run_row(be.as_ref(), &env, &spec)?;
            driver::record_row("table3", label, &res)?;
            let pref = paper
                .iter()
                .find(|(t, m, _)| t == tag && *m == res.method)
                .map(|(_, _, v)| *v);
            table.row(driver::cells(label, &res.method, &res, pref));
        }
    }
    table.print();
    println!("rows emitted: {}", table.rows.len());
    println!("shape checks:");
    println!("  - Ours train-params ≪ dense for every model (paper: 97% cut, ViT-t)");
    println!("  - RigL accuracy collapses on transformers (paper: 49.6 vs 64.3)");
    Ok(())
}
