//! Regenerates **Table 1**: one-linear-layer model on (synthetic) MNIST.
//!
//! Paper rows: block sizes (2,2) (4,2) (8,2) (16,2) × {group LASSO,
//! elastic group LASSO, blockwise RigL, Ours} + unstructured iterative
//! pruning + (for context) the dense model. Columns: accuracy, sparsity
//! rate, training params, training FLOPs.
//!
//! Shape checks (paper → here): Ours' params/FLOPs fall sharply with block
//! size while every baseline stays at the dense 7.84K; Ours ≈ baselines'
//! accuracy at (2,2) and trades accuracy at coarser blocks.
//!
//! Scale via env: BS_STEPS / BS_SEEDS / BS_TRAIN_N / BS_TEST_N. Runs on
//! whichever backend `backend::open_default` picks; specs the backend
//! cannot run (e.g. missing HLO artifacts) are skipped, not failed.

use blocksparse::bench::driver::{self, BenchEnv, ROW_HEADERS};
use blocksparse::bench::TableWriter;

// paper accuracy references per (block, method) for the inline comparison
const PAPER: &[(&str, &str, &str)] = &[
    ("(2,2)", "group_lasso", "85.18 ± 0.37"),
    ("(2,2)", "elastic_gl", "80.61 ± 0.44"),
    ("(2,2)", "rigl_block", "86.66 ± 0.36"),
    ("(2,2)", "kpd", "88.97 ± 1.50"),
    ("(4,2)", "group_lasso", "74.12 ± 0.98"),
    ("(4,2)", "elastic_gl", "76.66 ± 1.59"),
    ("(4,2)", "rigl_block", "87.13 ± 0.44"),
    ("(4,2)", "kpd", "81.75 ± 0.77"),
    ("(8,2)", "group_lasso", "75.82 ± 0.73"),
    ("(8,2)", "elastic_gl", "80.61 ± 0.44"),
    ("(8,2)", "rigl_block", "87.32 ± 0.38"),
    ("(8,2)", "kpd", "75.08 ± 2.05"),
    ("(16,2)", "group_lasso", "75.82 ± 0.73"),
    ("(16,2)", "elastic_gl", "80.61 ± 0.44"),
    ("(16,2)", "rigl_block", "86.95 ± 0.35"),
    ("(16,2)", "kpd", "81.57 ± 2.05"),
    ("-", "iter_prune", "86.72 ± 0.24"),
    ("-", "dense", "(not in table)"),
];

fn paper_ref(block: &str, method: &str) -> Option<&'static str> {
    PAPER.iter().find(|(b, m, _)| *b == block && *m == method).map(|(_, _, v)| *v)
}

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let be = blocksparse::backend::open_default()?;
    let env = BenchEnv::from_env(600, 3, 8192, 2048);
    let mut table = TableWriter::new(
        "Table 1 — linear model on synthetic-MNIST (paper: Table 1)",
        &ROW_HEADERS,
    );

    let blocks = ["b2x2", "b4x2", "b8x2", "b16x2"];
    let labels = ["(2,2)", "(4,2)", "(8,2)", "(16,2)"];
    for (bk, label) in blocks.iter().zip(labels) {
        for method in ["gl", "egl", "rigl", "kpd"] {
            let spec = format!("t1_{method}_{bk}");
            let Some(res) = driver::run_row_or_skip(be.as_ref(), &env, &spec)? else {
                continue;
            };
            driver::record_row("table1", label, &res)?;
            table.row(driver::cells(label, &res.method, &res,
                                    paper_ref(label, &res.method)));
        }
    }
    for spec in ["t1_prune", "t1_dense"] {
        let Some(res) = driver::run_row_or_skip(be.as_ref(), &env, spec)? else {
            continue;
        };
        driver::record_row("table1", "-", &res)?;
        table.row(driver::cells("-", &res.method, &res, paper_ref("-", &res.method)));
    }
    table.print();

    // headline shape assertions (printed, not hard failures)
    println!("shape checks:");
    println!("  - Ours train-params at (16,2) must be ≪ dense 7.84K (paper: 0.80K)");
    println!("  - baselines' params identical across block sizes (dense W)");
    Ok(())
}
