//! Property and gradient-check tests for the native backend's math.
//!
//! * KPD factorized forward ≡ `Tensor::kron`-materialized dense matmul
//!   across random (m1, m2, n1, n2, rank) shapes (`prop_check`);
//! * one `train_step` on the convex softmax-CE objective decreases loss;
//! * central-finite-difference gradient check of the KPD backward pass on
//!   a tiny 4×6 layer, covering all of the S / A (left) / B (right)
//!   factors.

use blocksparse::backend::native::{kpd, NativeBackend, SpecConfig};
use blocksparse::backend::Backend;
use blocksparse::flops::KpdDims;
use blocksparse::prop_assert;
use blocksparse::tensor::{HostValue, Tensor};
use blocksparse::testutil::{close, prop_check};
use blocksparse::util::rng::Rng;

#[test]
fn prop_kpd_forward_matches_kron_materialized_dense() {
    prop_check("native kpd forward == dense", 60, |g| {
        let (m1, n1) = (g.usize_in(1, 4), g.usize_in(1, 4));
        let (m2, n2) = (g.usize_in(1, 4), g.usize_in(1, 4));
        let r = g.usize_in(1, 3);
        let nb = g.usize_in(1, 5);
        let d = KpdDims { m1, n1, m2, n2, r };
        let (m, n) = (m1 * m2, n1 * n2);
        let x = g.normal_vec(nb * n);
        let s = g.uniform_vec(m1 * n1, -1.5, 1.5);
        let a = g.normal_vec(r * m1 * n1);
        let b = g.normal_vec(r * m2 * n2);

        let (z, _) = kpd::forward(&x, nb, &s, &a, &b, d);

        let st = Tensor::new(&[m1, n1], s.clone()).unwrap();
        let at = Tensor::new(&[r, m1, n1], a.clone()).unwrap();
        let bt = Tensor::new(&[r, m2, n2], b.clone()).unwrap();
        let w = Tensor::kpd_reconstruct(&st, &at, &bt).unwrap();
        for bb in 0..nb {
            for i in 0..m {
                let mut want = 0.0f32;
                for j in 0..n {
                    want += x[bb * n + j] * w.at2(i, j);
                }
                let got = z[bb * m + i];
                prop_assert!(
                    close(got, want, 1e-4, 1e-4),
                    "z[{bb},{i}] = {got} != {want} at {d:?}"
                );
            }
        }
        Ok(())
    });
}

fn fixed_batch(nb: usize, in_dim: usize, classes: usize, seed: u64) -> (HostValue, HostValue) {
    let mut rng = Rng::new(seed);
    let x = Tensor::from_fn(&[nb, in_dim], |_| rng.normal());
    let y: Vec<i32> = (0..nb).map(|i| (i % classes) as i32).collect();
    (HostValue::F32(x), HostValue::I32 { shape: vec![nb], data: y })
}

/// The softmax-CE objective of a linear model is convex; a small-lr step
/// on a fixed batch must strictly decrease the batch loss.
#[test]
fn prop_train_step_decreases_convex_loss() {
    prop_check("train_step decreases convex loss", 20, |g| {
        let seed = g.usize_in(0, 10_000) as u64;
        let mut cfg = SpecConfig::linear("cvx", "kpd", 12, 4, 2, 3, 2, 8);
        cfg.momentum = 0.0; // plain GD on a convex objective is monotone
        let be = NativeBackend::from_spec(cfg).map_err(|e| e.to_string())?;
        let mut state = be.init_state("cvx", g.case as u32).map_err(|e| e.to_string())?;
        let (x, y) = fixed_batch(8, 12, 4, seed);
        let before = be.eval_step(&state, &x, &y).map_err(|e| e.to_string())?[0];
        for _ in 0..5 {
            be.train_step(&mut state, &x, &y, &[0.0, 0.05]).map_err(|e| e.to_string())?;
        }
        let after = be.eval_step(&state, &x, &y).map_err(|e| e.to_string())?[0];
        prop_assert!(after < before, "loss went {before} -> {after} (seed {seed})");
        Ok(())
    });
}

/// Infer the analytic gradient from one plain-SGD step (momentum 0, λ 0:
/// p′ = p − lr·g, so g = (p − p′)/lr) and check it against central finite
/// differences of the eval loss, entry by entry, for S, A and B.
#[test]
fn kpd_gradient_check_on_tiny_4x6_layer() {
    // 4×6 layer: m2=2, n2=3 → grid 2×2, rank 2
    let mut cfg = SpecConfig::linear("gc", "kpd", 6, 4, 2, 3, 2, 8);
    cfg.momentum = 0.0;
    let be = NativeBackend::from_spec(cfg).unwrap();
    let (x, y) = fixed_batch(8, 6, 4, 99);
    let lr = 0.01f32;

    let state0 = be.init_state("gc", 3).unwrap();
    let mut stepped = be.init_state("gc", 3).unwrap();
    be.train_step(&mut stepped, &x, &y, &[0.0, lr]).unwrap();

    let h = 1e-2f32;
    for key in ["fc.S", "fc.A", "fc.B"] {
        let p0 = state0.param_tensor(key).unwrap();
        let p1 = stepped.param_tensor(key).unwrap();
        for idx in 0..p0.len() {
            let analytic = (p0.data()[idx] - p1.data()[idx]) / lr;
            let fd = {
                let mut probe = be.init_state("gc", 3).unwrap();
                let mut plus = p0.clone();
                plus.data_mut()[idx] += h;
                probe.set_param(key, plus).unwrap();
                let lp = be.eval_step(&probe, &x, &y).unwrap()[0];
                let mut minus = p0.clone();
                minus.data_mut()[idx] -= h;
                probe.set_param(key, minus).unwrap();
                let lm = be.eval_step(&probe, &x, &y).unwrap()[0];
                (lp - lm) / (2.0 * h)
            };
            assert!(
                close(fd, analytic, 3e-3, 3e-2),
                "{key}[{idx}]: finite-diff {fd} vs analytic {analytic}"
            );
        }
    }
}

/// The ℓ1 prox on S is exact: a pure-regularizer step (lr·λ ≥ max|S| with
/// zero gradient influence via a zero batch) zeroes every S entry.
#[test]
fn soft_threshold_produces_exact_zeros() {
    let cfg = SpecConfig::linear("zero", "kpd", 6, 4, 2, 3, 1, 4);
    let be = NativeBackend::from_spec(cfg).unwrap();
    let mut state = be.init_state("zero", 0).unwrap();
    // x = 0 ⇒ logits 0 ⇒ dS = 0; a huge λ then soft-thresholds S past zero
    let x = HostValue::F32(Tensor::zeros(&[4, 6]));
    let y = HostValue::I32 { shape: vec![4], data: vec![0, 1, 2, 3] };
    be.train_step(&mut state, &x, &y, &[200.0, 0.1]).unwrap();
    let s = state.param("fc.S").unwrap();
    assert!(s.data().iter().all(|&v| v == 0.0), "S = {:?}", s.data());
    // with S ≡ 0 the whole model is block-sparse: logits are exactly zero
    let (xr, yr) = fixed_batch(4, 6, 4, 1);
    let m = be.eval_step(&state, &xr, &yr).unwrap();
    assert!((m[0] - 4.0f32.ln()).abs() < 1e-5, "ce {}", m[0]);
}
