//! ISSUE-5 data-parallel determinism suite: R replica workers must be
//! **bit-identical** to 1 worker — final parameters, optimizer state,
//! metric streams, and the RigL controller's drop/grow decisions — for
//! any replica count, including non-dividing batches with a tail shard.
//!
//! The runs go through `Trainer::run_sharded` (the driver `cfg.replicas
//! > 1` delegates to; R = 1 is driven explicitly as the comparison
//! baseline) on the golden-run data pipeline from `tests/mlp.rs`, plus
//! direct `DataParallelTrainer` steps for the shard-level contracts.

use blocksparse::backend::native::simd::{self, SimdKind};
use blocksparse::backend::native::NativeBackend;
use blocksparse::backend::{Backend, TrainState};
use blocksparse::config::{Config, TrainConfig};
use blocksparse::coordinator::{self, Trainer};
use blocksparse::data::shard_ranges;
use blocksparse::metrics::History;
use blocksparse::tensor::{HostValue, Tensor};
use blocksparse::train::DataParallelTrainer;
use blocksparse::util::rng::Rng;

/// Pin the scalar kernels for the whole binary: the bit-identity
/// expectations here were produced by the scalar path, and every test
/// pins the same kind so the process-wide pin cannot race across the
/// concurrent test threads.
fn backend() -> NativeBackend {
    simd::force(SimdKind::Scalar);
    NativeBackend::with_default_specs()
}

fn quick_cfg(spec: &str, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::from_config(&Config::default(), spec);
    cfg.steps = steps;
    cfg.seeds = vec![0];
    cfg.eval_every = 0;
    cfg.train_examples = 512;
    cfg.test_examples = 128;
    cfg
}

fn assert_states_bit_identical(a: &TrainState, b: &TrainState, tag: &str) {
    assert_eq!(a.param_names, b.param_names, "{tag}: param layout");
    for (n, t) in a.param_names.iter().zip(&a.params) {
        let bt = b.param(n).unwrap();
        assert_eq!(t.data(), bt.data(), "{tag}: param '{n}' diverged");
    }
    assert_eq!(a.opt_names, b.opt_names, "{tag}: optimizer layout");
    for ((n, t), bt) in a.opt_names.iter().zip(&a.opt).zip(&b.opt) {
        assert_eq!(t.data(), bt.data(), "{tag}: optimizer slot '{n}' diverged");
    }
}

fn assert_histories_bit_identical(a: &History, b: &History, tag: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{tag}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.step, rb.step, "{tag}: record step");
        assert_eq!(
            ra.values.len(),
            rb.values.len(),
            "{tag}: record keys at step {}",
            ra.step
        );
        for (k, va) in &ra.values {
            let vb = rb.values.get(k).unwrap_or_else(|| {
                panic!("{tag}: metric '{k}' missing at step {}", ra.step)
            });
            // f64 bit equality: the metric streams must be the *same*
            assert_eq!(va, vb, "{tag}: metric '{k}' diverged at step {}", ra.step);
        }
    }
}

/// The acceptance-criteria run: a fixed-seed 50-step golden run of the
/// coarse-block Table-2 KPD MLP at R ∈ {1, 2, 4} — bit-identical final
/// params, optimizer state, and metric streams. R = 1 drives the sharded
/// loop directly; R = 2/4 go through `Trainer::run` with `cfg.replicas`
/// set, which also pins the delegation path.
#[test]
fn golden_t2_bit_identical_across_replicas() {
    let be = backend();
    let key = "t2_kpd_16x8_8x4_4x2";
    let mut cfg = quick_cfg(key, 50);
    cfg.lambda = 0.05;
    cfg.lr = 0.1;
    cfg.eval_every = 10; // test_acc/test_loss records must match too
    let spec = be.spec(key).unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 512, 128).unwrap();

    let trainer = Trainer::new(&be, &cfg);
    let base = trainer.run_sharded(1, 0, &train, &test).unwrap();
    assert!(base.test_loss.is_finite() && base.test_acc.is_finite());
    for r in [2usize, 4] {
        let mut cfg_r = cfg.clone();
        cfg_r.replicas = r;
        let trainer_r = Trainer::new(&be, &cfg_r);
        let out = trainer_r.run(0, &train, &test).unwrap();
        assert_states_bit_identical(&base.state, &out.state, &format!("R={r}"));
        assert_histories_bit_identical(&base.history, &out.history, &format!("R={r}"));
        assert_eq!(base.test_acc.to_bits(), out.test_acc.to_bits(), "R={r} test_acc");
        assert_eq!(base.test_loss.to_bits(), out.test_loss.to_bits(), "R={r} test_loss");
    }
}

/// Non-dividing batch: batch 96 at shard width 36 leaves a 24-example
/// tail shard; R = 1 and R = 4 (with a worker count that does not divide
/// the shard count either) must stay bit-identical.
#[test]
fn tail_shard_bit_identical() {
    simd::force(SimdKind::Scalar); // this test builds its backend directly
    assert_eq!(shard_ranges(96, 36), vec![(0, 36), (36, 36), (72, 24)]);
    let cfg = blocksparse::backend::native::SpecConfig::mlp(
        "tail96",
        "kpd",
        &[24, 16, 6],
        &[(2, 3), (2, 2)],
        2,
        96,
    );
    let be = NativeBackend::from_spec(cfg).unwrap();
    let mut rng = Rng::new(40);
    let x = Tensor::from_fn(&[96, 24], |_| rng.normal());
    let y: Vec<i32> = (0..96).map(|i| (i % 6) as i32).collect();
    let bx = HostValue::F32(x);
    let by = HostValue::I32 { shape: vec![96], data: y };

    let run = |replicas: usize| {
        let dp = DataParallelTrainer::new(&be, "tail96", replicas)
            .unwrap()
            .with_shard_width(36);
        let mut state = be.init_state("tail96", 2).unwrap();
        let mut metrics = Vec::new();
        for _ in 0..10 {
            metrics = dp.step(&mut state, &bx, &by, &[0.02, 0.1]).unwrap();
        }
        (state, metrics)
    };
    let (s1, m1) = run(1);
    let (s4, m4) = run(4);
    assert_eq!(m1, m4, "metrics diverged with a tail shard");
    assert_states_bit_identical(&s1, &s4, "tail shard");
}

/// RigL-under-parallelism regression: on a fixed-seed run across a prune
/// round, the drop/grow decisions (the masks) and the *reduced gradient-
/// norm tail* the controller consumes are identical for R = 1 vs R = 4.
#[test]
fn rigl_decisions_identical_across_replicas() {
    let be = backend();
    let key = "t2_rigl_8x4_4x4_2x2";
    let mut cfg = quick_cfg(key, 40);
    cfg.rigl_every = 10; // several mask updates inside the run
    let spec = be.spec(key).unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 512, 128).unwrap();
    let trainer = Trainer::new(&be, &cfg);
    let a = trainer.run_sharded(1, 0, &train, &test).unwrap();
    let b = trainer.run_sharded(4, 0, &train, &test).unwrap();
    assert_states_bit_identical(&a.state, &b.state, "rigl R=1 vs R=4");
    for slot in ["fc1", "fc2", "fc3"] {
        let ma = a.state.param(&format!("{slot}.mask")).unwrap();
        let mb = b.state.param(&format!("{slot}.mask")).unwrap();
        assert_eq!(ma.data(), mb.data(), "{slot} drop/grow decisions diverged");
        let active: f32 = ma.data().iter().sum();
        assert!(active > 0.0, "{slot}: no active blocks");
    }

    // the gnorm tail itself (a controller input the History never
    // records): one direct step must produce the identical full metrics
    // vector — named head *and* unnamed tail — for any R
    let gn = be.gnorm_len(key).unwrap();
    assert!(gn > 0);
    let idx: Vec<usize> = (0..spec.batch).collect();
    let batch = blocksparse::data::assemble_batch(&train, &idx).unwrap();
    let step_once = |replicas: usize| {
        let dp = DataParallelTrainer::new(&be, key, replicas).unwrap();
        let mut state = be.init_state(key, 0).unwrap();
        dp.step(&mut state, &batch.x, &batch.y, &[0.1]).unwrap()
    };
    let m1 = step_once(1);
    let m4 = step_once(4);
    assert_eq!(m1.len(), spec.metrics.len() + gn);
    assert_eq!(m1, m4, "reduced gnorm tail diverged across R");
}

/// The split path must compute the same math as the fused step: one
/// sharded step and one fused `train_step` from the same state agree on
/// every metric and parameter to float-accumulation tolerance, across
/// every native family (single-slot, mlp, pattern).
#[test]
fn sharded_step_matches_fused_step_all_families() {
    let be = backend();
    for key in [
        "qs_kpd",
        "t1_gl_b2x2",
        "t1_egl_b2x2",
        "t1_rigl_b2x2",
        "t1_prune",
        "t1_dense",
        "t2_kpd_8x4_4x4_2x2",
        "t2_dense",
        "f3a_pattern",
    ] {
        let spec = be.spec(key).unwrap().clone();
        let mut rng = Rng::new(7);
        let nb = 32usize;
        let x = Tensor::from_fn(&[nb, 784], |_| rng.normal());
        let y: Vec<i32> = (0..nb).map(|i| (i % 10) as i32).collect();
        let bx = HostValue::F32(x);
        let by = HostValue::I32 { shape: vec![nb], data: y };
        let hyper: Vec<f32> = spec
            .hyper
            .iter()
            .map(|h| match h.as_str() {
                "lr" => 0.05,
                "lambda2" => 1e-4,
                _ => 0.01,
            })
            .collect();

        let mut fused = be.init_state(key, 1).unwrap();
        let mf = be.train_step(&mut fused, &bx, &by, &hyper).unwrap();

        let dp = DataParallelTrainer::new(&be, key, 2).unwrap();
        let mut sharded = be.init_state(key, 1).unwrap();
        let ms = dp.step(&mut sharded, &bx, &by, &hyper).unwrap();

        assert_eq!(mf.len(), ms.len(), "{key}: metrics arity");
        for (i, (a, b)) in mf.iter().zip(&ms).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 + 1e-3 * a.abs(),
                "{key}: metric[{i}] fused {a} vs sharded {b}"
            );
        }
        for (n, t) in fused.param_names.iter().zip(&fused.params) {
            let st = sharded.param(n).unwrap();
            let diff = t.max_abs_diff(st);
            assert!(diff < 1e-4, "{key}: param '{n}' fused vs sharded diff {diff}");
        }
    }
}

/// `Trainer::run` with `replicas > 1` on a backend without a separable
/// gradient path must fall back to the fused loop, not fail — here
/// emulated by the constructor contract (unknown specs / replicas = 0
/// are rejected by `DataParallelTrainer::new`, and the trainer only
/// delegates when `supports_grad_step` says so).
#[test]
fn driver_preconditions() {
    let be = backend();
    assert!(!be.supports_grad_step("no_such_spec"));
    assert!(DataParallelTrainer::new(&be, "no_such_spec", 2).is_err());
    assert!(DataParallelTrainer::new(&be, "qs_kpd", 0).is_err());
    // grad_len matches what grad_step actually produces
    for key in ["qs_kpd", "t1_dense", "t2_kpd_16x8_8x4_4x2", "f3a_pattern"] {
        let want = be.grad_len(key).unwrap();
        let state = be.init_state(key, 0).unwrap();
        let mut rng = Rng::new(3);
        let x = Tensor::from_fn(&[8, 784], |_| rng.normal());
        let y: Vec<i32> = (0..8).map(|i| (i % 10) as i32).collect();
        let g = be
            .grad_step(
                &state,
                &HostValue::F32(x),
                &HostValue::I32 { shape: vec![8], data: y },
            )
            .unwrap();
        assert_eq!(g.grad_sum.len(), want, "{key}: grad_len vs grad_step");
        assert_eq!(g.examples, 8, "{key}");
        assert!(g.ce_sum.is_finite() && g.correct >= 0.0, "{key}");
    }
}
