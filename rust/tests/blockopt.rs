//! Block-size search (`blockopt`) integration tests: the ISSUE-9
//! acceptance criteria, end-to-end on the native backend.
//!
//! * the cost-model artifact round-trips through a real file and prices
//!   uncalibrated shapes through the nearest-area fallback;
//! * one short joint pattern training run + a hand-built cost model that
//!   makes the max-retention survivor the most expensive shape: the
//!   unconstrained recommendation must equal the Figure-3 survivor, and a
//!   tight latency budget must switch the recommendation to a strictly
//!   cheaper shape — the subsystem's two headline behaviours, pinned.

use blocksparse::backend::native::simd::{self, SimdKind};
use blocksparse::backend::native::{NativeBackend, SpecConfig};
use blocksparse::blockopt::cost::{shape_key, CostModel, ShapeModel, CALIB_GRID};
use blocksparse::blockopt::pareto;
use blocksparse::blockopt::sweep::{self, Measured};
use blocksparse::config::{Config, TrainConfig};
use blocksparse::coordinator::probe;

/// All tests pin the scalar kernels (the pin is process-wide and every
/// test pins the same kind, so concurrent test threads cannot race) —
/// sweep measurements must not depend on the host's SIMD tier.
fn backend() -> NativeBackend {
    simd::force(SimdKind::Scalar);
    NativeBackend::from_spec(SpecConfig::pattern(
        "bo_pattern",
        64,
        8,
        &[(2, 2), (2, 4), (2, 8), (2, 16)],
        1,
        32,
    ))
    .expect("bo_pattern spec is valid")
}

fn quick_cfg(steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::from_config(&Config::default(), "bo_pattern");
    cfg.steps = steps;
    cfg.seeds = vec![0];
    cfg.eval_every = 0;
    cfg.train_examples = 1024;
    cfg.test_examples = 256;
    blocksparse::backend::native::pattern::calibrate_lambda(&mut cfg, "native-cpu");
    cfg
}

fn shape(m2: usize, n2: usize, a_ns: f64) -> ShapeModel {
    ShapeModel { m2, n2, a_ns, c_ns: 50.0, points: vec![] }
}

fn model_of(shapes: Vec<ShapeModel>) -> CostModel {
    CostModel {
        simd: "scalar".into(),
        dtype: "f32".into(),
        grid: CALIB_GRID,
        batch: 32,
        entries: shapes.into_iter().map(|s| (shape_key(s.m2, s.n2), s)).collect(),
    }
}

#[test]
fn cost_model_file_round_trip_and_fallback_pricing() {
    let _ = backend(); // pin SIMD like every other test in this binary
    let m = model_of(vec![shape(2, 2, 2.0), shape(2, 16, 0.5)]);
    let dir = std::env::temp_dir().join("bs_blockopt_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cost_model.json");
    m.save(&path).unwrap();
    let back = CostModel::load(&path).unwrap();
    assert_eq!(back, m);
    // 2x4 (area 8) is uncalibrated: priced through the nearest-area
    // entry (2x2, area 4) rather than failing the sweep
    let priced = back.predict_ms(8, 64, 2, 4, 32, 0.5).unwrap();
    assert!(priced > 0.0);
    let exact = back.predict_ms(8, 64, 2, 2, 32, 0.5).unwrap();
    assert!(exact > 0.0);
}

/// The acceptance run: measure once, then score the same measurement
/// against a cost model rigged so the Figure-3 survivor is the most
/// expensive candidate. Unconstrained → survivor wins; tight budget →
/// the recommendation switches to a strictly cheaper block shape.
#[test]
fn sweep_matches_survivor_unconstrained_and_switches_under_budget() {
    let be = backend();
    let cfg = quick_cfg(150);
    let nb = 32usize;
    let measured = sweep::measure_candidates(&be, &cfg).unwrap();
    assert_eq!(measured.len(), 4);
    for m in &measured {
        assert!(m.retention.is_finite() && m.retention >= 0.0, "retention {m:?}");
        assert!((0.0..=1.0).contains(&m.occupancy), "occupancy {m:?}");
        assert_eq!(m.slots, vec![(8, 64, m.m2, m.n2)]);
    }
    let rets: Vec<f64> = measured.iter().map(|m| m.retention).collect();
    let survivor = probe::pattern_survivor(&rets);
    let surv_shape = (measured[survivor].m2, measured[survivor].n2);

    // the rigged model: the survivor's shape costs 500-1000× per MAC
    let shapes: Vec<ShapeModel> = measured
        .iter()
        .map(|m| {
            let a_ns = if (m.m2, m.n2) == surv_shape { 1000.0 } else { 2.0 };
            shape(m.m2, m.n2, a_ns)
        })
        .collect();
    let model = model_of(shapes);

    let out = sweep::score(&measured, &model, nb, None).unwrap();
    assert_eq!(out.survivor, survivor, "score must reuse the shared survivor criterion");
    assert_eq!(
        out.recommended, out.survivor,
        "unconstrained, the front pick is the Figure-3 survivor"
    );
    // the front is sorted by latency and contains no dominated point
    for w in out.front.windows(2) {
        assert!(w[0].latency_ms < w[1].latency_ms);
        assert!(w[0].retention < w[1].retention);
    }
    for a in &out.front {
        for b in &out.front {
            assert!(!pareto::dominates(b, a), "{b:?} dominates front member {a:?}");
        }
    }

    // a budget that only admits the cheapest candidate forces the switch
    let min_lat = out
        .candidates
        .iter()
        .map(|c| c.pred_latency_ms)
        .fold(f64::INFINITY, f64::min);
    let surv_lat = out
        .candidates
        .iter()
        .find(|c| c.pattern == out.survivor)
        .unwrap()
        .pred_latency_ms;
    assert!(surv_lat > min_lat, "the rigged model must make the survivor expensive");
    let tight = sweep::score(&measured, &model, nb, Some(min_lat)).unwrap();
    assert_eq!(tight.survivor, survivor, "the budget must not change the survivor");
    assert_ne!(tight.recommended, tight.survivor, "the budget must switch the pick");
    let rec_lat = tight
        .candidates
        .iter()
        .find(|c| c.pattern == tight.recommended)
        .unwrap()
        .pred_latency_ms;
    assert!(rec_lat <= min_lat + 1e-12, "the pick must respect the budget");
    assert!(rec_lat < surv_lat, "the pick must be strictly cheaper than the survivor");

    // scoring is deterministic under a shuffled measurement order
    let mut shuffled: Vec<Measured> = measured.clone();
    shuffled.reverse();
    assert_eq!(sweep::score(&shuffled, &model, nb, None).unwrap(), out);

    // and the cost-aware blend interpolates between the two picks:
    // alpha 0 is retention-only (the survivor), alpha 1 latency-only
    let lats: Vec<f64> = out.candidates.iter().map(|c| c.pred_latency_ms).collect();
    assert_eq!(probe::pattern_survivor_cost_aware(&rets, &lats, 0.0).unwrap(), survivor);
    let cheapest = probe::pattern_survivor_cost_aware(&rets, &lats, 1.0).unwrap();
    assert!((lats[cheapest] - min_lat).abs() < 1e-12);
}

/// `candidate_shapes` reads the spec's declared pattern grid in
/// first-seen order — what both the CLI's in-process calibration and the
/// bench calibrate against.
#[test]
fn candidate_shapes_come_from_the_spec_grid() {
    let be = backend();
    let spec = blocksparse::backend::Backend::spec(&be, "bo_pattern").unwrap().clone();
    let shapes = sweep::candidate_shapes(&spec).unwrap();
    assert_eq!(shapes, vec![(2, 2), (2, 4), (2, 8), (2, 16)]);
}
