//! Multi-layer (`mlp` family / Table-2) integration tests: the native
//! backend training real multi-layer KPD networks end-to-end, offline.
//!
//! * the `t2_*` registry trains through the full Trainer stack (data →
//!   steps → per-layer probes) with improving loss and above-chance acc;
//! * a fixed-seed 50-step **golden run** pins final loss and per-layer
//!   block sparsity against the bit-faithful Python mirror
//!   (`python/tests/golden_mlp_mirror.py`), so refactors of the backward
//!   chain cannot silently drift;
//! * checkpoint round-trip: a mid-run snapshot restored into a fresh
//!   state continues training **bit-identically**;
//! * the RigL / pruning controllers keep their per-slot / global
//!   contracts on the stack.

use blocksparse::backend::native::simd::{self, SimdKind};
use blocksparse::backend::native::NativeBackend;
use blocksparse::backend::{Backend, TrainState};
use blocksparse::checkpoint::Checkpoint;
use blocksparse::config::{Config, TrainConfig};
use blocksparse::coordinator::{self, experiment, probe, Trainer};
use blocksparse::tensor::{HostValue, Tensor};
use blocksparse::util::rng::Rng;

/// Every test's entry point — and the place the SIMD path is pinned off.
/// The golden expectations in this binary were produced by the scalar
/// kernels (and are mirrored bit-faithfully in Python), so the pin keeps
/// them valid on AVX2/NEON hosts. All tests pin the same kind, so the
/// process-wide pin cannot race across the concurrent test threads.
fn backend() -> NativeBackend {
    simd::force(SimdKind::Scalar);
    NativeBackend::with_default_specs()
}

fn quick_cfg(spec: &str, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::from_config(&Config::default(), spec);
    cfg.steps = steps;
    cfg.seeds = vec![0];
    cfg.eval_every = 0;
    cfg.train_examples = 1024;
    cfg.test_examples = 256;
    cfg
}

/// The golden run's deterministic dataset — must stay in lockstep with
/// `make_data` in python/tests/golden_mlp_mirror.py: one Rng(123) stream
/// draws 10 class templates (784 uniforms in [-1,1) each), then
/// per-example noise; x = 0.8·tmpl[y] + 0.5·noise, y = i % 10.
fn golden_data() -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(123);
    let tmpl: Vec<f32> = (0..10 * 784).map(|_| rng.uniform() * 2.0 - 1.0).collect();
    let noise: Vec<f32> = (0..256 * 784).map(|_| rng.uniform() * 2.0 - 1.0).collect();
    let mut x = vec![0.0f32; 256 * 784];
    let mut y = vec![0i32; 256];
    for i in 0..256 {
        let c = i % 10;
        y[i] = c as i32;
        for j in 0..784 {
            x[i * 784 + j] = 0.8 * tmpl[c * 784 + j] + 0.5 * noise[i * 784 + j];
        }
    }
    (x, y)
}

fn golden_batch(x: &[f32], y: &[i32], step: usize) -> (HostValue, HostValue) {
    let lo = (step % 4) * 64;
    let bx = HostValue::F32(
        Tensor::new(&[64, 784], x[lo * 784..(lo + 64) * 784].to_vec()).unwrap(),
    );
    let by = HostValue::I32 { shape: vec![64], data: y[lo..lo + 64].to_vec() };
    (bx, by)
}

/// ISSUE-3 golden-run regression: 50 fixed-seed steps of the coarse-block
/// Table-2 KPD spec at λ=0.2, lr=0.1 — calibrated mid-collapse, where the
/// pinned values are sensitive to any drift in the backward chain. The
/// expected values come from python/tests/golden_mlp_mirror.py (f64
/// 18.425011 / f32 18.425205 loss; the mirror run is stable to <1e-3
/// under f32↔f64 and under 1e-6 init perturbations, so these tolerances
/// leave ≥ 60× margin for accumulation-order/libm differences while
/// catching any semantic change).
#[test]
fn golden_t2_mlp_fifty_steps() {
    let be = backend();
    let key = "t2_kpd_16x8_8x4_4x2";
    let entry = be.spec(key).unwrap().clone();
    let mut state = be.init_state(key, 0).unwrap();
    let (x, y) = golden_data();
    let mut last = Vec::new();
    for step in 0..50 {
        let (bx, by) = golden_batch(&x, &y, step);
        last = be.train_step(&mut state, &bx, &by, &[0.2, 0.1]).unwrap();
    }
    // metrics layout: [loss, ce, acc, s_l1, s_l1_fc1, s_l1_fc2, s_l1_fc3]
    assert_eq!(last.len(), entry.metrics.len());
    assert!((last[0] - 18.425).abs() < 0.5, "final loss drifted: {}", last[0]);
    assert!((last[1] - 2.1188).abs() < 0.1, "final ce drifted: {}", last[1]);
    assert!(last[2] > 0.9, "final train acc collapsed: {}", last[2]);
    let want_s = [46.07f32, 26.98, 8.48];
    for (i, want) in want_s.iter().enumerate() {
        assert!(
            (last[4 + i] - want).abs() < 3.0,
            "s_l1_fc{}: {} vs golden {}",
            i + 1,
            last[4 + i],
            want
        );
    }
    assert!((last[3] - want_s.iter().sum::<f32>()).abs() < 6.0, "total s_l1 {}", last[3]);

    // per-layer block sparsity of the materialized stack
    let layers = probe::layer_sparsity(&be, &entry, &state).unwrap();
    assert_eq!(layers.len(), 3);
    let want_sp = [14.7f64, 29.1, 28.0];
    for ((name, rate), want) in layers.iter().zip(&want_sp) {
        assert!(
            (rate - want).abs() < 6.0,
            "{name}: block sparsity {rate:.2}% vs golden {want}%"
        );
    }
}

/// ISSUE-3 checkpoint coverage: snapshot a multi-layer state mid-run,
/// restore into a *differently seeded* fresh state, and drive both down
/// the same batch schedule — continued training must be bit-identical in
/// every parameter and optimizer slot.
#[test]
fn checkpoint_roundtrip_resumes_bit_identical() {
    let be = backend();
    let key = "t2_kpd_8x4_4x4_2x2";
    let (x, y) = golden_data();
    let hyper = [0.02f32, 0.05];
    let run_steps =
        |be: &NativeBackend, state: &mut TrainState, from: usize, to: usize| {
            for step in from..to {
                let (bx, by) = golden_batch(&x, &y, step);
                be.train_step(state, &bx, &by, &hyper).unwrap();
            }
        };

    let mut state = be.init_state(key, 1).unwrap();
    run_steps(&be, &mut state, 0, 10);
    let dir = std::env::temp_dir().join("bs_mlp_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.bsck");
    Checkpoint::from_state(&state).save(&path).unwrap();
    run_steps(&be, &mut state, 10, 20);

    // the restore target starts from a different seed: every value must
    // come from the snapshot, not from luck
    let mut restored = be.init_state(key, 999).unwrap();
    Checkpoint::load(&path).unwrap().restore_state(&mut restored).unwrap();
    run_steps(&be, &mut restored, 10, 20);

    for (n, t) in state.param_names.iter().zip(&state.params) {
        let rt = restored.param(n).unwrap();
        assert_eq!(t.data(), rt.data(), "param '{n}' diverged after restore");
    }
    for ((n, t), rt) in state.opt_names.iter().zip(&state.opt).zip(&restored.opt) {
        assert_eq!(t.data(), rt.data(), "optimizer slot '{n}' diverged after restore");
    }
}

/// The acceptance-criteria run: a Table-2 KPD MLP trained through the
/// Trainer on the synthetic dataset beats its init loss and chance acc.
#[test]
fn t2_mlp_kpd_trains_end_to_end() {
    let be = backend();
    let mut cfg = quick_cfg("t2_kpd_16x8_8x4_4x2", 150);
    cfg.lr = 0.05;
    cfg.lambda = 0.008;
    let spec = be.spec(&cfg.spec).unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let trainer = Trainer::new(&be, &cfg);
    let init_state = be.init_state(&cfg.spec, 0).unwrap();
    let (_, init_loss, _) = trainer.evaluate(&init_state, &spec, &test).unwrap();
    let outcome = trainer.run(0, &train, &test).unwrap();
    assert!(
        outcome.test_loss < init_loss,
        "loss did not improve: {init_loss} -> {}",
        outcome.test_loss
    );
    assert!(outcome.test_acc > 20.0, "acc {:.2}% not above chance", outcome.test_acc);
    // per-layer s_l1 series reach the history
    for slot in ["fc1", "fc2", "fc3"] {
        let series = outcome.history.series(&format!("s_l1_{slot}"));
        assert_eq!(series.len(), cfg.steps, "missing s_l1_{slot} series");
    }
}

/// Every t2 method family completes a short sweep with finite metrics,
/// valid whole-model sparsity, and a 3-slot per-layer breakdown.
#[test]
fn t2_sweep_all_methods_with_per_layer_probes() {
    let be = backend();
    for key in
        ["t2_kpd_4x4_4x4_2x2", "t2_gl_2x2_2x2_2x2", "t2_egl_4x4_2x2_2x2",
         "t2_rigl_8x4_4x4_2x2", "t2_prune", "t2_dense"]
    {
        let mut cfg = quick_cfg(key, 20);
        cfg.lambda = 0.01;
        let res = experiment::run_spec(&be, &cfg).unwrap();
        assert!(res.acc_mean.is_finite(), "{key}");
        assert!((0.0..=100.0).contains(&res.sparsity_mean), "{key}: {}", res.sparsity_mean);
        assert_eq!(res.layer_sparsity.len(), 3, "{key} per-layer breakdown");
        for (j, (name, m, s)) in res.layer_sparsity.iter().enumerate() {
            assert_eq!(name, &format!("fc{}", j + 1), "{key} slot order");
            assert!((0.0..=100.0).contains(m), "{key}/{name}: {m}");
            assert!(s.is_finite());
        }
    }
}

/// The trainer's pruning controller on a multi-layer spec reaches the
/// *global* target, and global magnitude ranking prunes the small-scale
/// first layer harder than the larger-scale last layer (the signature
/// that ranking really is whole-model, not per-slot).
#[test]
fn t2_prune_schedule_hits_global_target() {
    let be = backend();
    let mut cfg = quick_cfg("t2_prune", 60);
    cfg.prune_rounds = 2;
    cfg.prune_target = 0.5;
    let spec = be.spec("t2_prune").unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let outcome = Trainer::new(&be, &cfg).run(0, &train, &test).unwrap();
    let sp = probe::measure_sparsity(&be, &spec, &outcome.state).unwrap();
    assert!((sp - 50.0).abs() < 1.0, "global prune sparsity {sp}");
    let layers = probe::layer_sparsity(&be, &spec, &outcome.state).unwrap();
    // fc1 weights are init-scaled √(1/784), fc3 √(1/100): a global
    // magnitude threshold must hit fc1 well harder than fc3
    assert!(
        layers[0].1 > layers[2].1 + 5.0,
        "global ranking missing: fc1 {:.1}% vs fc3 {:.1}%",
        layers[0].1,
        layers[2].1
    );
}

/// RigL on the stack: the trainer's mask update preserves each slot's
/// active-block budget independently.
#[test]
fn t2_rigl_training_preserves_per_slot_budgets() {
    let be = backend();
    let key = "t2_rigl_8x4_4x4_2x2";
    let mut cfg = quick_cfg(key, 60);
    cfg.rigl_every = 50;
    let init = be.init_state(key, 0).unwrap();
    let budgets = |st: &TrainState| -> Vec<f32> {
        ["fc1", "fc2", "fc3"]
            .iter()
            .map(|s| st.param(&format!("{s}.mask")).unwrap().data().iter().sum())
            .collect()
    };
    let before = budgets(&init);
    let spec = be.spec(key).unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let outcome = Trainer::new(&be, &cfg).run(0, &train, &test).unwrap();
    assert_eq!(before, budgets(&outcome.state), "per-slot budgets drifted");
    assert!(outcome.test_acc.is_finite());
}

/// Multi-layer materialize: one dense W per slot at the stack shapes.
#[test]
fn t2_materialize_shapes_per_slot() {
    let be = backend();
    for key in ["t2_kpd_16x8_8x4_4x2", "t2_gl_2x2_2x2_2x2", "t2_prune", "t2_dense"] {
        let state = be.init_state(key, 1).unwrap();
        let ws = be.materialize(&state).unwrap();
        assert_eq!(ws.len(), 3, "{key}");
        assert_eq!(ws[0].0, "fc1");
        assert_eq!(ws[0].1.shape(), &[304, 784], "{key}");
        assert_eq!(ws[1].1.shape(), &[100, 304], "{key}");
        assert_eq!(ws[2].1.shape(), &[10, 100], "{key}");
        for (_, w) in &ws {
            assert!(w.data().iter().all(|v| v.is_finite()), "{key}");
        }
    }
}

/// Table-2 accounting directions: factorized params ≪ dense at the coarse
/// combo, and the factorized step is cheaper than the dense-parameterized
/// baselines there (Prop. 2 compounding over the stack).
#[test]
fn t2_accounting_directions() {
    let be = backend();
    let kpd = experiment::accounting(be.spec("t2_kpd_16x8_8x4_4x2").unwrap());
    let gl = experiment::accounting(be.spec("t2_gl_16x8_8x4_4x2").unwrap());
    assert!(kpd.0 < gl.0 / 4, "params {} !< {}/4", kpd.0, gl.0);
    assert!(kpd.1 < gl.1, "step flops {} !< {}", kpd.1, gl.1);
}
