//! Serving-engine robustness end-to-end: bounded admission with typed
//! load-shed, sustained-overload accounting, and atomic hot-swap under
//! concurrent clients — logits from two model generations must never mix
//! within a request, and no request may be dropped across a swap.

use std::sync::Arc;

use blocksparse::infer::engine::{drive_overload, Engine, EngineError, EngineOpts};
use blocksparse::infer::registry::ModelRegistry;
use blocksparse::infer::{bsr, BsrLayer, BsrModel};
use blocksparse::util::rng::Rng;

/// A small 16→12→6 stack (2×2 blocks) — big enough to batch, cheap
/// enough to hammer from 64 threads.
fn model(seed: u64) -> BsrModel {
    let mut rng = Rng::new(seed);
    let w1: Vec<f32> = (0..12 * 16).map(|_| rng.normal()).collect();
    let w2: Vec<f32> = (0..6 * 12).map(|_| rng.normal()).collect();
    BsrModel {
        spec: format!("serve{seed}"),
        method: "dense".into(),
        in_dim: 16,
        out_dim: 6,
        layers: vec![
            BsrLayer::from_dense("fc1", &w1, 12, 16, 2, 2).unwrap(),
            BsrLayer::from_dense("fc2", &w2, 6, 12, 2, 2).unwrap(),
        ],
    }
}

#[test]
fn full_queue_sheds_typed_and_recovers() {
    let engine = Engine::new(
        model(1),
        EngineOpts { max_batch: 4, workers: 1, queue_depth: 2 },
    )
    .unwrap();
    engine.pause();
    let queued: Vec<Result<_, EngineError>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let engine = &engine;
                s.spawn(move || engine.predict(&[0.5; 16]))
            })
            .collect();
        while engine.stats().depth < 2 {
            std::thread::yield_now();
        }
        // at the bound: shed synchronously with the typed error, never block
        match engine.predict(&[0.5; 16]) {
            Err(EngineError::Overloaded { depth }) => assert_eq!(depth, 2),
            other => panic!("wanted Overloaded, got {other:?}"),
        }
        engine.resume();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for r in queued {
        r.expect("queued requests must complete after resume");
    }
    let st = engine.stats();
    assert_eq!((st.accepted, st.shed, st.completed, st.failed), (2, 1, 2, 0));
    assert!(st.peak_depth <= 2);
}

#[test]
fn sustained_overload_stays_bounded_and_accounts_every_request() {
    let engine = Engine::new(
        model(2),
        EngineOpts { max_batch: 4, workers: 2, queue_depth: 8 },
    )
    .unwrap();
    assert_eq!(engine.capacity(), 8 + 2 * 4);
    // 4× capacity, zero think time
    let rep = drive_overload(&engine, 16, 4 * engine.capacity(), 0xACE).unwrap();
    assert_eq!(rep.offered, 16 * 64);
    assert_eq!(rep.accepted + rep.shed, rep.offered, "requests unaccounted for");
    assert_eq!(rep.accepted_lat_ms.len(), rep.accepted);
    assert!(rep.accepted > 0, "an overloaded engine must still serve");
    assert!(rep.shed > 0, "64 zero-think clients vs capacity 16 must shed");
    assert!(
        rep.peak_depth <= rep.queue_depth,
        "backlog breached the admission bound: {} > {}",
        rep.peak_depth,
        rep.queue_depth
    );
    assert!((rep.offered_ratio - 4.0).abs() < 1e-12);
    assert!(rep.accepted_lat_ms.iter().all(|&v| v.is_finite() && v >= 0.0));
    let st = engine.stats();
    assert_eq!(st.accepted, rep.accepted as u64);
    assert_eq!(st.shed, rep.shed as u64);
    assert_eq!(st.completed, rep.accepted as u64);
}

/// Hot-swap under concurrent clients: every response must carry logits
/// that exactly match the generation it claims — engine forwards are
/// bitwise-equal to `bsr::model_forward(model, x, 1)` regardless of
/// batching, so any old/new interleave within a request is detectable as
/// an exact mismatch. And no request may be dropped across the swaps.
#[test]
fn hot_swap_never_mixes_generations_and_drops_nothing() {
    let a = model(3);
    let b = model(4);
    let (ref_a, ref_b) = (a.clone(), b.clone());
    let engine = Arc::new(
        Engine::new(a, EngineOpts { max_batch: 8, workers: 4, queue_depth: 256 }).unwrap(),
    );
    let swaps = 6usize;
    let clients = 8usize;
    let per_client = 40usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let engine = engine.clone();
                let (ref_a, ref_b) = (&ref_a, &ref_b);
                s.spawn(move || {
                    let mut rng = Rng::new(0x500 + c as u64);
                    for _ in 0..per_client {
                        let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
                        let p = engine.predict(&x).expect("no request may be dropped");
                        // generations alternate a, b, a, b, ... from 0
                        let expect_model = if p.generation % 2 == 0 { ref_a } else { ref_b };
                        let want = bsr::model_forward(expect_model, &x, 1).unwrap();
                        assert_eq!(
                            p.logits, want,
                            "logits do not match generation {} exactly",
                            p.generation
                        );
                    }
                })
            })
            .collect();
        // swap back and forth while the clients hammer the engine
        for i in 0..swaps {
            std::thread::sleep(std::time::Duration::from_millis(3));
            let variant = if i % 2 == 0 { ref_b.clone() } else { ref_a.clone() };
            let generation = engine.swap_model(variant).unwrap();
            assert_eq!(generation, i as u64 + 1);
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    let st = engine.stats();
    assert_eq!(st.accepted, (clients * per_client) as u64);
    assert_eq!(st.completed, st.accepted);
    assert_eq!((st.shed, st.failed), (0, 0));
    assert_eq!(engine.generation(), swaps as u64);
}

/// Registry + atomic on-disk publish: deploy from a path, republish the
/// artifact in place (save is write-then-rename), redeploy, and the name
/// hot-swaps to the new weights on the same engine.
#[test]
fn registry_redeploys_from_republished_artifact() {
    let dir = std::env::temp_dir().join("bs_serve_registry_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("live.bsm");
    let a = model(5);
    let b = model(6);
    a.save(&path).unwrap();
    let reg = ModelRegistry::new(EngineOpts { max_batch: 4, workers: 2, queue_depth: 32 });
    assert_eq!(reg.deploy_from_path("live", &path).unwrap(), 0);
    let engine = reg.get("live").unwrap();
    let x = [0.3f32; 16];
    assert_eq!(
        engine.predict(&x).unwrap().logits,
        bsr::model_forward(&a, &x, 1).unwrap()
    );
    // republish the same path (atomic overwrite), redeploy under the name
    b.save(&path).unwrap();
    assert_eq!(reg.deploy_from_path("live", &path).unwrap(), 1);
    // the engine object survived: same queue, new weights
    assert!(Arc::ptr_eq(&engine, &reg.get("live").unwrap()));
    let p = engine.predict(&x).unwrap();
    assert_eq!(p.generation, 1);
    assert_eq!(p.logits, bsr::model_forward(&b, &x, 1).unwrap());
    assert_eq!(reg.names(), vec!["live".to_string()]);
    assert!(reg.undeploy("live"));
}
