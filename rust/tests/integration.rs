//! Integration tests: the full L3 stack against the real AOT artifacts.
//!
//! These need `make artifacts` to have run; they skip (with a message)
//! when artifacts/ is missing so `cargo test` stays green in a fresh
//! checkout. A single shared Runtime keeps PJRT client setup cost down.

use blocksparse::config::{Config, TrainConfig};
use blocksparse::coordinator::{self, experiment, probe, Trainer};
use blocksparse::data::assemble_batch;
use blocksparse::runtime::Runtime;

/// PJRT clients are not Send/Sync (Rc inside the xla crate), so each test
/// opens its own Runtime on its own thread; compile caches are per-test.
fn runtime() -> Option<Runtime> {
    let dir = blocksparse::artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

macro_rules! rt_or_skip {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => return,
        }
    };
}

fn quick_cfg(spec: &str, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::from_config(&Config::default(), spec);
    cfg.steps = steps;
    cfg.seeds = vec![0];
    cfg.eval_every = 0;
    cfg.train_examples = 1024;
    cfg.test_examples = 256;
    cfg
}

#[test]
fn init_is_seed_deterministic() {
    let rt = rt_or_skip!();
    let a = rt.init_state("qs_kpd", 7).unwrap();
    let b = rt.init_state("qs_kpd", 7).unwrap();
    let c = rt.init_state("qs_kpd", 8).unwrap();
    let ta = a.param_tensor("fc.A").unwrap();
    let tb = b.param_tensor("fc.A").unwrap();
    let tc = c.param_tensor("fc.A").unwrap();
    assert_eq!(ta.data(), tb.data());
    assert_ne!(ta.data(), tc.data());
    // S starts at ones, biases at zero
    let s = a.param_tensor("fc.S").unwrap();
    assert!(s.data().iter().all(|&v| v == 1.0));
}

#[test]
fn train_step_updates_params_and_returns_finite_metrics() {
    let rt = rt_or_skip!();
    let spec = rt.spec("qs_kpd").unwrap().clone();
    let (train, _) = coordinator::dataset_for(&spec, 1, 256, 64).unwrap();
    let mut state = rt.init_state("qs_kpd", 0).unwrap();
    let before = state.param_tensor("fc.A").unwrap();
    let idx: Vec<usize> = (0..spec.batch).collect();
    let b = assemble_batch(&train, &idx).unwrap();
    let m = rt.train_step(&mut state, &b.x, &b.y, &[0.01, 0.1]).unwrap();
    assert_eq!(m.len(), spec.metrics.len());
    assert!(m.iter().all(|v| v.is_finite()), "{m:?}");
    let after = state.param_tensor("fc.A").unwrap();
    assert!(before.max_abs_diff(&after) > 0.0, "params did not move");
}

#[test]
fn loss_decreases_over_training() {
    let rt = rt_or_skip!();
    let cfg = quick_cfg("qs_kpd", 120);
    let spec = rt.spec("qs_kpd").unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let outcome = Trainer::new(&rt, &cfg).run(0, &train, &test).unwrap();
    let series = outcome.history.series("loss");
    let head: f64 = series[..10].iter().map(|(_, v)| v).sum::<f64>() / 10.0;
    let tail: f64 =
        series[series.len() - 10..].iter().map(|(_, v)| v).sum::<f64>() / 10.0;
    assert!(tail < head, "loss did not decrease: {head} -> {tail}");
    assert!(outcome.test_acc > 20.0, "acc {}% not above chance", outcome.test_acc);
}

#[test]
fn materialize_matches_host_reconstruction() {
    let rt = rt_or_skip!();
    let state = rt.init_state("qs_kpd", 3).unwrap();
    let ws = rt.materialize(&state).unwrap();
    assert_eq!(ws.len(), 1);
    let (name, w) = &ws[0];
    assert_eq!(name, "fc");
    assert_eq!(w.shape(), &[10, 784]);
    // host-side Eq. 3 reconstruction must agree with the HLO one
    let s = state.param_tensor("fc.S").unwrap();
    let a = state.param_tensor("fc.A").unwrap();
    let b = state.param_tensor("fc.B").unwrap();
    let host = blocksparse::tensor::Tensor::kpd_reconstruct(&s, &a, &b).unwrap();
    assert!(w.max_abs_diff(&host) < 1e-4, "diff {}", w.max_abs_diff(&host));
}

#[test]
fn rigl_controller_preserves_block_count() {
    let rt = rt_or_skip!();
    let spec = rt.spec("t1_rigl_b2x2").unwrap().clone();
    let mut state = rt.init_state("t1_rigl_b2x2", 0).unwrap();
    let mask0 = state.param_tensor("fc.mask").unwrap();
    let nnz0: f32 = mask0.data().iter().sum();
    // feed fake gradient norms (distinct values so threshold ties are rare)
    let gnorm: Vec<f32> = (0..mask0.len()).map(|i| i as f32 * 0.37 + 0.01).collect();
    rt.rigl_update(&mut state, &gnorm, 0.3).unwrap();
    let mask1 = state.param_tensor("fc.mask").unwrap();
    let nnz1: f32 = mask1.data().iter().sum();
    // drop/grow is threshold-based: magnitude ties may admit a few extra
    // blocks — allow 1% drift
    assert!(
        (nnz0 - nnz1).abs() <= (0.01 * mask0.len() as f32).max(1.0),
        "nnz changed {nnz0} -> {nnz1}"
    );
    assert!(mask0.max_abs_diff(&mask1) > 0.0, "mask did not change");
}

#[test]
fn prune_executable_hits_target() {
    let rt = rt_or_skip!();
    let mut state = rt.init_state("t1_prune", 0).unwrap();
    rt.prune(&mut state, 0.6).unwrap();
    let mask = state.param_tensor("fc.emask").unwrap();
    let sparsity = blocksparse::sparsity::mask_sparsity(&mask);
    assert!((sparsity - 0.6).abs() < 0.02, "sparsity {sparsity}");
}

#[test]
fn full_sweep_on_tiny_budget_all_methods() {
    let rt = rt_or_skip!();
    for spec in ["t1_kpd_b2x2", "t1_gl_b2x2", "t1_egl_b2x2", "t1_rigl_b2x2",
                 "t1_prune", "t1_dense"] {
        let mut cfg = quick_cfg(spec, 40);
        cfg.lambda = 0.01;
        let res = experiment::run_spec(&rt, &cfg).unwrap();
        assert!(res.acc_mean.is_finite(), "{spec}");
        assert!(res.train_params > 0, "{spec}");
        assert!(res.step_flops > 0, "{spec}");
    }
}

#[test]
fn pattern_spec_reports_all_series() {
    let rt = rt_or_skip!();
    let cfg = quick_cfg("f3a_pattern", 30);
    let spec = rt.spec("f3a_pattern").unwrap().clone();
    let k = spec.num_patterns().unwrap();
    assert_eq!(k, 4);
    let (train, test) = coordinator::dataset_for(&spec, 1, 1024, 256).unwrap();
    let outcome = Trainer::new(&rt, &cfg).run(0, &train, &test).unwrap();
    for p in 0..k {
        let s = outcome.history.series(&format!("s_l1_p{p}"));
        assert_eq!(s.len(), 30, "pattern {p} series incomplete");
        assert!(s.iter().all(|(_, v)| v.is_finite() && *v >= 0.0));
    }
    assert_eq!(outcome.pattern_accs.len(), k);
    let norms = probe::pattern_s_norms(&spec, &outcome.state).unwrap();
    assert_eq!(norms.len(), k);
}

#[test]
fn lm_spec_trains_and_counts_token_accuracy() {
    let rt = rt_or_skip!();
    let mut cfg = quick_cfg("it_lm_kpd", 30);
    cfg.lr = 3e-3;
    cfg.lambda = 1e-4;
    cfg.train_examples = 256;
    cfg.test_examples = 64;
    let res = experiment::run_spec(&rt, &cfg).unwrap();
    assert!(res.acc_mean > 0.0 && res.acc_mean <= 100.0);
}

#[test]
fn eval_accuracy_in_bounds_for_all_quick_specs() {
    let rt = rt_or_skip!();
    let spec = rt.spec("t1_dense").unwrap().clone();
    let (_, test) = coordinator::dataset_for(&spec, 1, 1024, 512).unwrap();
    let state = rt.init_state("t1_dense", 0).unwrap();
    let cfg = quick_cfg("t1_dense", 1);
    let tr = Trainer::new(&rt, &cfg);
    let (acc, loss, _) = tr.evaluate(&state, &spec, &test).unwrap();
    assert!((0.0..=100.0).contains(&acc));
    assert!(loss.is_finite());
}

#[test]
fn sparsity_probe_runs_for_every_method_family() {
    let rt = rt_or_skip!();
    for spec_key in ["t1_kpd_b2x2", "t1_gl_b2x2", "t1_rigl_b2x2", "t1_prune",
                     "t1_dense"] {
        let spec = rt.spec(spec_key).unwrap().clone();
        let state = rt.init_state(spec_key, 0).unwrap();
        let s = probe::measure_sparsity(&rt, &spec, &state).unwrap();
        assert!((0.0..=100.0).contains(&s), "{spec_key}: {s}");
    }
}

#[test]
fn accounting_shapes_match_paper_directions() {
    let rt = rt_or_skip!();
    // Ours at (16,2) must be far below dense at the same shapes (Table 1)
    let kpd = experiment::accounting(rt.spec("t1_kpd_b16x2").unwrap());
    let gl = experiment::accounting(rt.spec("t1_gl_b16x2").unwrap());
    assert!(kpd.0 < gl.0 / 4, "params {} vs {}", kpd.0, gl.0);
    assert!(kpd.1 < gl.1, "flops {} vs {}", kpd.1, gl.1);
    // transformer: the 97%-reduction headline direction (Table 3)
    let kpd3 = experiment::accounting(rt.spec("t3_vit_t_kpd").unwrap());
    let dense3 = experiment::accounting(rt.spec("t3_vit_t_dense").unwrap());
    assert!(kpd3.0 < dense3.0 / 2, "{} vs {}", kpd3.0, dense3.0);
}
