//! Integration tests: the full L3 stack (data → trainer → probes) running
//! end-to-end on the default `NativeBackend` — no AOT artifacts, no PJRT,
//! no python. These are the repo's tier-1 behavioral guarantees:
//!
//! * a KPD linear model trains to lower loss than at init and above-chance
//!   accuracy on the synthetic MNIST substitute;
//! * a high ℓ1 weight on S drives ≥ 50% *block* sparsity, and strictly
//!   more sparsity than λ = 0;
//! * every method family (kpd / group LASSO / elastic / RigL / pruning /
//!   dense) completes a sweep with finite metrics and valid probes.

use blocksparse::backend::{Backend, TrainState};
use blocksparse::backend::native::NativeBackend;
use blocksparse::config::{Config, TrainConfig};
use blocksparse::coordinator::{self, experiment, probe, Trainer};
use blocksparse::sparsity;

fn backend() -> NativeBackend {
    NativeBackend::with_default_specs()
}

fn quick_cfg(spec: &str, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::from_config(&Config::default(), spec);
    cfg.steps = steps;
    cfg.seeds = vec![0];
    cfg.eval_every = 0;
    cfg.train_examples = 1024;
    cfg.test_examples = 256;
    cfg
}

#[test]
fn init_is_seed_deterministic() {
    let be = backend();
    let a = be.init_state("qs_kpd", 7).unwrap();
    let b = be.init_state("qs_kpd", 7).unwrap();
    let c = be.init_state("qs_kpd", 8).unwrap();
    assert_eq!(a.param("fc.A").unwrap().data(), b.param("fc.A").unwrap().data());
    assert_ne!(a.param("fc.A").unwrap().data(), c.param("fc.A").unwrap().data());
    // S starts at ones so every block is initially alive
    assert!(a.param("fc.S").unwrap().data().iter().all(|&v| v == 1.0));
}

/// The acceptance-criteria run: a real KPD linear model, trained through
/// the Trainer on the synthetic dataset, must beat its init loss and
/// chance accuracy.
#[test]
fn kpd_linear_trains_end_to_end() {
    let be = backend();
    let mut cfg = quick_cfg("qs_kpd", 300);
    cfg.lr = 0.02;
    cfg.lambda = 0.005;
    let spec = be.spec("qs_kpd").unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let trainer = Trainer::new(&be, &cfg);

    let init_state = be.init_state("qs_kpd", 0).unwrap();
    let (init_acc, init_loss, _) = trainer.evaluate(&init_state, &spec, &test).unwrap();

    let outcome = trainer.run(0, &train, &test).unwrap();
    assert!(
        outcome.test_loss < init_loss,
        "loss did not improve: {init_loss} -> {}",
        outcome.test_loss
    );
    assert!(
        outcome.test_acc > 20.0,
        "acc {:.2}% not above chance (init {:.2}%)",
        outcome.test_acc,
        init_acc
    );
    // training loss series also trends down
    let series = outcome.history.series("loss");
    let head: f64 = series[..10].iter().map(|(_, v)| v).sum::<f64>() / 10.0;
    let tail: f64 =
        series[series.len() - 10..].iter().map(|(_, v)| v).sum::<f64>() / 10.0;
    assert!(tail < head, "train loss did not decrease: {head} -> {tail}");
}

fn train_kpd_with_lambda(lambda: f64) -> (TrainState, f64) {
    let be = backend();
    let mut cfg = quick_cfg("t1_kpd_b16x2", 300);
    cfg.lr = 0.05;
    cfg.lambda = lambda;
    let spec = be.spec("t1_kpd_b16x2").unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let outcome = Trainer::new(&be, &cfg).run(0, &train, &test).unwrap();
    let sp = probe::measure_sparsity(&be, &spec, &outcome.state).unwrap();
    (outcome.state, sp)
}

/// High λ must produce majority block sparsity (the paper's mechanism:
/// ℓ1-shrunk S entries kill whole blocks), and strictly more than λ = 0.
#[test]
fn high_lambda_reaches_majority_block_sparsity() {
    let (state, sp_high) = train_kpd_with_lambda(0.15);
    assert!(sp_high >= 50.0, "block sparsity {sp_high:.1}% < 50% at high λ");
    // the prox produces exact zeros in S
    let s = state.param("fc.S").unwrap();
    let exact_zeros = s.data().iter().filter(|v| **v == 0.0).count();
    assert!(exact_zeros > 0, "soft-threshold never zeroed an S entry");

    let (_, sp_zero) = train_kpd_with_lambda(0.0);
    assert!(
        sp_high > sp_zero,
        "sparsity regression: λ=0.15 gives {sp_high:.1}%, λ=0 gives {sp_zero:.1}%"
    );
}

#[test]
fn materialize_is_block_structured() {
    let be = backend();
    let state = be.init_state("qs_kpd", 3).unwrap();
    let ws = be.materialize(&state).unwrap();
    assert_eq!(ws.len(), 1);
    let (name, w) = &ws[0];
    assert_eq!(name, "fc");
    assert_eq!(w.shape(), &[10, 784]);
    assert!(w.data().iter().all(|v| v.is_finite()));
}

#[test]
fn full_sweep_on_tiny_budget_all_methods() {
    let be = backend();
    for spec in ["t1_kpd_b2x2", "t1_gl_b2x2", "t1_egl_b2x2", "t1_rigl_b2x2",
                 "t1_prune", "t1_dense"] {
        let mut cfg = quick_cfg(spec, 40);
        cfg.lambda = 0.01;
        let res = experiment::run_spec(&be, &cfg).unwrap();
        assert!(res.acc_mean.is_finite(), "{spec}");
        assert!(res.train_params > 0, "{spec}");
        assert!(res.step_flops > 0, "{spec}");
        assert!((0.0..=100.0).contains(&res.sparsity_mean), "{spec}: {}", res.sparsity_mean);
    }
}

/// The pruning controller inside the trainer hits its gradual targets.
#[test]
fn iter_prune_schedule_reaches_final_target() {
    let be = backend();
    let mut cfg = quick_cfg("t1_prune", 60);
    cfg.prune_rounds = 2;
    cfg.prune_target = 0.5;
    let spec = be.spec("t1_prune").unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let outcome = Trainer::new(&be, &cfg).run(0, &train, &test).unwrap();
    let emask = outcome.state.param("fc.emask").unwrap().clone();
    let sp = sparsity::mask_sparsity(&emask);
    assert!((sp - 0.5).abs() < 0.01, "final prune sparsity {sp}");
}

/// RigL training keeps the active-block budget constant across the mask
/// update the trainer schedules at step `rigl_every`.
#[test]
fn rigl_training_preserves_block_budget() {
    let be = backend();
    let mut cfg = quick_cfg("t1_rigl_b2x2", 120);
    cfg.rigl_every = 100;
    let init = be.init_state("t1_rigl_b2x2", 0).unwrap();
    let nnz0: f32 = init.param("fc.mask").unwrap().data().iter().sum();
    let spec = be.spec("t1_rigl_b2x2").unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let outcome = Trainer::new(&be, &cfg).run(0, &train, &test).unwrap();
    let nnz1: f32 = outcome.state.param("fc.mask").unwrap().data().iter().sum();
    assert_eq!(nnz0, nnz1, "active block count drifted {nnz0} -> {nnz1}");
    assert!(outcome.test_acc.is_finite());
}

#[test]
fn eval_accuracy_in_bounds_at_init() {
    let be = backend();
    let spec = be.spec("t1_dense").unwrap().clone();
    let (_, test) = coordinator::dataset_for(&spec, 1, 1024, 512).unwrap();
    let state = be.init_state("t1_dense", 0).unwrap();
    let cfg = quick_cfg("t1_dense", 1);
    let tr = Trainer::new(&be, &cfg);
    let (acc, loss, _) = tr.evaluate(&state, &spec, &test).unwrap();
    assert!((0.0..=100.0).contains(&acc));
    assert!(loss.is_finite());
}

#[test]
fn sparsity_probe_runs_for_every_method_family() {
    let be = backend();
    for spec_key in ["t1_kpd_b2x2", "t1_gl_b2x2", "t1_rigl_b2x2", "t1_prune",
                     "t1_dense"] {
        let spec = be.spec(spec_key).unwrap().clone();
        let state = be.init_state(spec_key, 0).unwrap();
        let s = probe::measure_sparsity(&be, &spec, &state).unwrap();
        assert!((0.0..=100.0).contains(&s), "{spec_key}: {s}");
    }
}

#[test]
fn accounting_shapes_match_paper_directions() {
    let be = backend();
    // Ours at (16,2) must be far below dense at the same shapes (Table 1)
    let kpd = experiment::accounting(be.spec("t1_kpd_b16x2").unwrap());
    let gl = experiment::accounting(be.spec("t1_gl_b16x2").unwrap());
    assert!(kpd.0 < gl.0 / 4, "params {} vs {}", kpd.0, gl.0);
    assert!(kpd.1 < gl.1, "flops {} vs {}", kpd.1, gl.1);
    // rank ablation: params grow with r (Table 4 direction)
    let r1 = experiment::accounting(be.spec("t4_linear_r1").unwrap());
    let r6 = experiment::accounting(be.spec("t4_linear_r6").unwrap());
    assert!(r6.0 > r1.0);
}
