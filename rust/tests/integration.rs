//! Integration tests: the full L3 stack (data → trainer → probes) running
//! end-to-end on the default `NativeBackend` — no AOT artifacts, no PJRT,
//! no python. These are the repo's tier-1 behavioral guarantees:
//!
//! * a KPD linear model trains to lower loss than at init and above-chance
//!   accuracy on the synthetic MNIST substitute;
//! * a high ℓ1 weight on S drives ≥ 50% *block* sparsity, and strictly
//!   more sparsity than λ = 0;
//! * every method family (kpd / group LASSO / elastic / RigL / pruning /
//!   dense) completes a sweep with finite metrics and valid probes.

use blocksparse::backend::{Backend, TrainState};
use blocksparse::backend::native::NativeBackend;
use blocksparse::config::{Config, TrainConfig};
use blocksparse::coordinator::{self, experiment, probe, Trainer};
use blocksparse::sparsity;

fn backend() -> NativeBackend {
    NativeBackend::with_default_specs()
}

fn quick_cfg(spec: &str, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::from_config(&Config::default(), spec);
    cfg.steps = steps;
    cfg.seeds = vec![0];
    cfg.eval_every = 0;
    cfg.train_examples = 1024;
    cfg.test_examples = 256;
    cfg
}

#[test]
fn init_is_seed_deterministic() {
    let be = backend();
    let a = be.init_state("qs_kpd", 7).unwrap();
    let b = be.init_state("qs_kpd", 7).unwrap();
    let c = be.init_state("qs_kpd", 8).unwrap();
    assert_eq!(a.param("fc.A").unwrap().data(), b.param("fc.A").unwrap().data());
    assert_ne!(a.param("fc.A").unwrap().data(), c.param("fc.A").unwrap().data());
    // S starts at ones so every block is initially alive
    assert!(a.param("fc.S").unwrap().data().iter().all(|&v| v == 1.0));
}

/// The acceptance-criteria run: a real KPD linear model, trained through
/// the Trainer on the synthetic dataset, must beat its init loss and
/// chance accuracy.
#[test]
fn kpd_linear_trains_end_to_end() {
    let be = backend();
    let mut cfg = quick_cfg("qs_kpd", 300);
    cfg.lr = 0.02;
    cfg.lambda = 0.005;
    let spec = be.spec("qs_kpd").unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let trainer = Trainer::new(&be, &cfg);

    let init_state = be.init_state("qs_kpd", 0).unwrap();
    let (init_acc, init_loss, _) = trainer.evaluate(&init_state, &spec, &test).unwrap();

    let outcome = trainer.run(0, &train, &test).unwrap();
    assert!(
        outcome.test_loss < init_loss,
        "loss did not improve: {init_loss} -> {}",
        outcome.test_loss
    );
    assert!(
        outcome.test_acc > 20.0,
        "acc {:.2}% not above chance (init {:.2}%)",
        outcome.test_acc,
        init_acc
    );
    // training loss series also trends down
    let series = outcome.history.series("loss");
    let head: f64 = series[..10].iter().map(|(_, v)| v).sum::<f64>() / 10.0;
    let tail: f64 =
        series[series.len() - 10..].iter().map(|(_, v)| v).sum::<f64>() / 10.0;
    assert!(tail < head, "train loss did not decrease: {head} -> {tail}");
}

fn train_kpd_with_lambda(lambda: f64) -> (TrainState, f64) {
    let be = backend();
    let mut cfg = quick_cfg("t1_kpd_b16x2", 300);
    cfg.lr = 0.05;
    cfg.lambda = lambda;
    let spec = be.spec("t1_kpd_b16x2").unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let outcome = Trainer::new(&be, &cfg).run(0, &train, &test).unwrap();
    let sp = probe::measure_sparsity(&be, &spec, &outcome.state).unwrap();
    (outcome.state, sp)
}

/// High λ must produce majority block sparsity (the paper's mechanism:
/// ℓ1-shrunk S entries kill whole blocks), and strictly more than λ = 0.
#[test]
fn high_lambda_reaches_majority_block_sparsity() {
    let (state, sp_high) = train_kpd_with_lambda(0.15);
    assert!(sp_high >= 50.0, "block sparsity {sp_high:.1}% < 50% at high λ");
    // the prox produces exact zeros in S
    let s = state.param("fc.S").unwrap();
    let exact_zeros = s.data().iter().filter(|v| **v == 0.0).count();
    assert!(exact_zeros > 0, "soft-threshold never zeroed an S entry");

    let (_, sp_zero) = train_kpd_with_lambda(0.0);
    assert!(
        sp_high > sp_zero,
        "sparsity regression: λ=0.15 gives {sp_high:.1}%, λ=0 gives {sp_zero:.1}%"
    );
}

#[test]
fn materialize_is_block_structured() {
    let be = backend();
    let state = be.init_state("qs_kpd", 3).unwrap();
    let ws = be.materialize(&state).unwrap();
    assert_eq!(ws.len(), 1);
    let (name, w) = &ws[0];
    assert_eq!(name, "fc");
    assert_eq!(w.shape(), &[10, 784]);
    assert!(w.data().iter().all(|v| v.is_finite()));
}

#[test]
fn full_sweep_on_tiny_budget_all_methods() {
    let be = backend();
    for spec in ["t1_kpd_b2x2", "t1_gl_b2x2", "t1_egl_b2x2", "t1_rigl_b2x2",
                 "t1_prune", "t1_dense"] {
        let mut cfg = quick_cfg(spec, 40);
        cfg.lambda = 0.01;
        let res = experiment::run_spec(&be, &cfg).unwrap();
        assert!(res.acc_mean.is_finite(), "{spec}");
        assert!(res.train_params > 0, "{spec}");
        assert!(res.step_flops > 0, "{spec}");
        assert!((0.0..=100.0).contains(&res.sparsity_mean), "{spec}: {}", res.sparsity_mean);
    }
}

/// The pruning controller inside the trainer hits its gradual targets.
#[test]
fn iter_prune_schedule_reaches_final_target() {
    let be = backend();
    let mut cfg = quick_cfg("t1_prune", 60);
    cfg.prune_rounds = 2;
    cfg.prune_target = 0.5;
    let spec = be.spec("t1_prune").unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let outcome = Trainer::new(&be, &cfg).run(0, &train, &test).unwrap();
    let emask = outcome.state.param("fc.emask").unwrap().clone();
    let sp = sparsity::mask_sparsity(&emask);
    assert!((sp - 0.5).abs() < 0.01, "final prune sparsity {sp}");
}

/// RigL training keeps the active-block budget constant across the mask
/// update the trainer schedules at step `rigl_every`.
#[test]
fn rigl_training_preserves_block_budget() {
    let be = backend();
    let mut cfg = quick_cfg("t1_rigl_b2x2", 120);
    cfg.rigl_every = 100;
    let init = be.init_state("t1_rigl_b2x2", 0).unwrap();
    let nnz0: f32 = init.param("fc.mask").unwrap().data().iter().sum();
    let spec = be.spec("t1_rigl_b2x2").unwrap().clone();
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, 1024, 256).unwrap();
    let outcome = Trainer::new(&be, &cfg).run(0, &train, &test).unwrap();
    let nnz1: f32 = outcome.state.param("fc.mask").unwrap().data().iter().sum();
    assert_eq!(nnz0, nnz1, "active block count drifted {nnz0} -> {nnz1}");
    assert!(outcome.test_acc.is_finite());
}

/// Classification data from a rank-1 KPD teacher that is *block-sparse at
/// 2×16*: W* = (S* ⊙ A*) ⊗ B* with half the (2,16) blocks zeroed. This is
/// the paper's own setting for Figure 3 — the data has a *right* block
/// size, so exactly one candidate of the joint pattern spec can represent
/// the teacher (a rank-1 2×16 teacher needs rank ≥ 2 at block 2×8 and
/// rank ≥ 8 at 2×2), and pattern selection is well-posed.
fn teacher_weights(rng: &mut blocksparse::util::rng::Rng) -> Vec<f32> {
    let (m1, n1, m2, n2) = (5usize, 49usize, 2usize, 16usize);
    let (m, nf) = (m1 * m2, n1 * n2);
    let s_star: Vec<f32> =
        (0..m1 * n1).map(|_| if rng.uniform() < 0.5 { 1.0 } else { 0.0 }).collect();
    let a_star: Vec<f32> =
        (0..m1 * n1).map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 }).collect();
    let b_star: Vec<f32> = (0..m2 * n2).map(|_| rng.normal()).collect();
    // W* = kron(S* ⊙ A*, B*), scaled so the mean row square-norm is 6²
    let mut w = vec![0.0f32; m * nf];
    for i1 in 0..m1 {
        for j1 in 0..n1 {
            let sa = s_star[i1 * n1 + j1] * a_star[i1 * n1 + j1];
            for i2 in 0..m2 {
                for j2 in 0..n2 {
                    w[(i1 * m2 + i2) * nf + j1 * n2 + j2] = sa * b_star[i2 * n2 + j2];
                }
            }
        }
    }
    let msq: f64 =
        w.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / m as f64;
    let scale = (6.0 / msq.sqrt()) as f32;
    for v in w.iter_mut() {
        *v *= scale;
    }
    w
}

/// Sample `n` examples labeled by the teacher `w` (argmax logits, 2% label
/// noise so the CE floor keeps gradients alive), consuming `rng` in order:
/// all X draws, then the per-example flip decisions.
fn teacher_samples(
    w: &[f32],
    rng: &mut blocksparse::util::rng::Rng,
    n: usize,
) -> blocksparse::data::Dataset {
    let (m, nf) = (10usize, 784usize);
    let x: Vec<f32> = (0..n * nf).map(|_| rng.normal()).collect();
    let mut y = vec![0i32; n];
    for (s, yi) in y.iter_mut().enumerate() {
        let row = &x[s * nf..(s + 1) * nf];
        let mut best = f32::NEG_INFINITY;
        for c in 0..m {
            let z: f32 = row.iter().zip(&w[c * nf..(c + 1) * nf]).map(|(a, b)| a * b).sum();
            if z > best {
                best = z;
                *yi = c as i32;
            }
        }
    }
    for yi in y.iter_mut() {
        if rng.uniform() < 0.02 {
            *yi = rng.below(10) as i32;
        }
    }
    blocksparse::data::Dataset::from_images(nf, m, x, y).unwrap()
}

/// ISSUE-2 acceptance, Figure 3a: jointly training the four block-size
/// candidates with the staircase-λ ramp on 2×16-block-structured data must
/// select exactly one survivor — the 2×16 pattern keeps the majority of
/// its initial ‖S‖₁ while every other candidate collapses below 10%.
#[test]
fn fig3_pattern_selection_exactly_one_survivor() {
    let be = backend();
    let spec = be.spec("f3a_pattern").unwrap().clone();
    let k = spec.num_patterns().unwrap();
    assert_eq!(k, 4);

    // one teacher labels both splits: the train stream (teacher draws →
    // X → flips from Rng(84)) pins the validated trajectory, the held-out
    // set reuses W* with an independent sample stream
    let mut rng = blocksparse::util::rng::Rng::new(84);
    let w_star = teacher_weights(&mut rng);
    let train = teacher_samples(&w_star, &mut rng, 1792);
    let mut test_rng = blocksparse::util::rng::Rng::new(84 ^ 0x7E57);
    let test = teacher_samples(&w_star, &mut test_rng, 256);
    let mut cfg = quick_cfg("f3a_pattern", 1000);
    cfg.lr = 0.05;
    // pinned λ schedule this test's dynamics were validated at — must be
    // the shipped calibration, so recalibrating LAMBDA_CALIBRATION forces
    // a conscious revalidation of this test
    cfg.lambda = 0.002;
    cfg.lambda_ramp = 0.0005;
    cfg.ramp_every = 300; // staircase: 0.002 → 0.0035 over the run
    assert_eq!(
        (cfg.lambda, cfg.lambda_ramp),
        blocksparse::backend::native::pattern::LAMBDA_CALIBRATION,
        "pattern λ calibration changed: revalidate the pinned retention outcome"
    );
    let trainer = Trainer::new(&be, &cfg);
    let outcome = trainer.run(0, &train, &test).unwrap();

    // the staircase actually ramped: the s_l1 series must exist per pattern
    for p in 0..k {
        let series = outcome.history.series(&format!("s_l1_p{p}"));
        assert_eq!(series.len(), cfg.steps, "missing s_l1_p{p} series");
    }

    // S^(k) init is all-ones, so retention = final ‖S‖₁ / entry count —
    // the shared survivor criterion from the probe layer
    let retention = probe::pattern_retention(&spec, &outcome.state).unwrap();
    // sanity-pin the 2×16 normalization: grid is 5×49 = 245 entries
    let finals = probe::pattern_s_norms(&spec, &outcome.state).unwrap();
    assert!((retention[3] - finals[3] / 245.0).abs() < 1e-12);
    // the probe's JSON-derived retention must agree with the backend's
    // dims-based twin that `materialize` uses for survivor extraction
    {
        use blocksparse::backend::native::pattern;
        use blocksparse::flops::KpdDims;
        let dims: Vec<KpdDims> = [(2, 2), (2, 4), (2, 8), (2, 16)]
            .iter()
            .map(|&(m2, n2)| KpdDims::from_block(10, 784, m2, n2, 1))
            .collect();
        let internal = pattern::retention(&outcome.state, &dims).unwrap();
        for (a, b) in retention.iter().zip(&internal) {
            assert!((a - b).abs() < 1e-12, "survivor criteria diverged: {retention:?} vs {internal:?}");
        }
        assert_eq!(
            pattern::survivor(&outcome.state, &dims).unwrap(),
            probe::pattern_survivor(&retention),
            "materialize's survivor disagrees with the reported survivor"
        );
    }
    let survivors: Vec<usize> =
        (0..k).filter(|&p| retention[p] > 0.5).collect();
    let collapsed: Vec<usize> =
        (0..k).filter(|&p| retention[p] < 0.1).collect();
    assert_eq!(
        survivors,
        vec![3],
        "expected exactly the 2×16 pattern to survive; retention {retention:?}"
    );
    assert_eq!(
        collapsed.len(),
        3,
        "every non-survivor must collapse below 10%; retention {retention:?}"
    );

    // survivor extraction: materialize returns the 2×16 pattern's dense W
    let ws = be.materialize(&outcome.state).unwrap();
    assert_eq!(ws.len(), 1);
    assert_eq!(ws[0].1.shape(), &[10, 784]);
}

/// ISSUE-2 acceptance: evaluation covers *every* test example. With
/// `test.n % batch != 0` the trailing partial batch must be scored, and
/// the resulting accuracy must be identical to a batch-size-1 sweep
/// (loss matches up to f32 summation order).
#[test]
fn eval_partial_tail_matches_batch_size_one_sweep() {
    let be = backend();
    let spec = be.spec("t1_dense").unwrap().clone();
    let (_, test) = coordinator::dataset_for(&spec, 5, 1024, 300).unwrap();
    assert!(
        test.n % spec.batch != 0,
        "test set must not divide the batch ({} % {})",
        test.n,
        spec.batch
    );
    let state = be.init_state("t1_dense", 2).unwrap();
    let cfg = quick_cfg("t1_dense", 1);
    let tr = Trainer::new(&be, &cfg);
    let (acc, loss, _) = tr.evaluate(&state, &spec, &test).unwrap();

    // hand-computed full sweep, one example at a time
    let mut correct = 0.0f64;
    let mut ce_sum = 0.0f64;
    for i in 0..test.n {
        let b = blocksparse::data::assemble_batch(&test, &[i]).unwrap();
        let m = be.eval_step(&state, &b.x, &b.y).unwrap();
        ce_sum += m[0] as f64;
        correct += m[1] as f64;
    }
    let want_acc = 100.0 * correct / test.n as f64;
    let want_loss = ce_sum / test.n as f64;
    assert_eq!(acc, want_acc, "partial-batch eval dropped or double-counted examples");
    assert!(
        (loss - want_loss).abs() < 1e-4,
        "batch-weighted mean loss {loss} != per-example sweep {want_loss}"
    );
}

/// A panicking closure must neither kill a pool worker for the rest of
/// the process nor hide its payload behind a misleading expect message.
#[test]
fn thread_pool_map_survives_a_panicking_job() {
    use blocksparse::util::pool::ThreadPool;
    let pool = ThreadPool::new(2);
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.map(6, |i| {
            if i == 2 {
                panic!("integration boom");
            }
            i * 10
        })
    }));
    let payload = caught.expect_err("the job's panic must reach the caller");
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .unwrap_or("<non-str payload>");
    assert!(msg.contains("integration boom"), "payload lost: {msg}");
    // the pool still has all its workers: further maps complete normally
    let out = pool.map(20, |i| i + 1).unwrap();
    assert_eq!(out, (1..=20).collect::<Vec<_>>());
}

#[test]
fn eval_accuracy_in_bounds_at_init() {
    let be = backend();
    let spec = be.spec("t1_dense").unwrap().clone();
    let (_, test) = coordinator::dataset_for(&spec, 1, 1024, 512).unwrap();
    let state = be.init_state("t1_dense", 0).unwrap();
    let cfg = quick_cfg("t1_dense", 1);
    let tr = Trainer::new(&be, &cfg);
    let (acc, loss, _) = tr.evaluate(&state, &spec, &test).unwrap();
    assert!((0.0..=100.0).contains(&acc));
    assert!(loss.is_finite());
}

#[test]
fn sparsity_probe_runs_for_every_method_family() {
    let be = backend();
    for spec_key in ["t1_kpd_b2x2", "t1_gl_b2x2", "t1_rigl_b2x2", "t1_prune",
                     "t1_dense"] {
        let spec = be.spec(spec_key).unwrap().clone();
        let state = be.init_state(spec_key, 0).unwrap();
        let s = probe::measure_sparsity(&be, &spec, &state).unwrap();
        assert!((0.0..=100.0).contains(&s), "{spec_key}: {s}");
    }
}

#[test]
fn accounting_shapes_match_paper_directions() {
    let be = backend();
    // Ours at (16,2) must be far below dense at the same shapes (Table 1)
    let kpd = experiment::accounting(be.spec("t1_kpd_b16x2").unwrap());
    let gl = experiment::accounting(be.spec("t1_gl_b16x2").unwrap());
    assert!(kpd.0 < gl.0 / 4, "params {} vs {}", kpd.0, gl.0);
    assert!(kpd.1 < gl.1, "flops {} vs {}", kpd.1, gl.1);
    // rank ablation: params grow with r (Table 4 direction)
    let r1 = experiment::accounting(be.spec("t4_linear_r1").unwrap());
    let r6 = experiment::accounting(be.spec("t4_linear_r6").unwrap());
    assert!(r6.0 > r1.0);
}
