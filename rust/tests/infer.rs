//! End-to-end tests of the train→export→serve pipeline: a trained Table-2
//! MLP spec exported to a BSR artifact must serve logits matching the
//! training backend's own evaluation, and export must preserve exactly the
//! block structure training produced (RigL masks, pruning masks).

use blocksparse::backend::native::{linalg, NativeBackend};
use blocksparse::backend::Backend;
use blocksparse::coordinator::dataset_for;
use blocksparse::data::{assemble_batch, Batcher};
use blocksparse::infer::engine::{Engine, EngineOpts};
use blocksparse::infer::{self, bsr, BsrModel};

/// The acceptance-criteria round trip: train `t2_kpd_16x8_8x4_4x2` for a
/// few steps, export to BSR, save+load the artifact, and serve a held-out
/// batch through the engine — logits must match the backend's own forward
/// (and therefore `eval_step`'s CE) within 1e-4.
#[test]
fn t2_mlp_round_trip_matches_eval_step() {
    let be = NativeBackend::with_default_specs();
    let spec_key = "t2_kpd_16x8_8x4_4x2";
    let spec = be.spec(spec_key).unwrap().clone();
    let (train, test) = dataset_for(&spec, 7, 512, 128).unwrap();
    let mut state = be.init_state(spec_key, 0).unwrap();
    let mut batcher = Batcher::new(&train, spec.batch, 1, true);
    // λ high enough that the ℓ1 prox zeroes real S entries: the per-step
    // threshold is lr·λ = 0.02 against the S init of 1.0, so exact zeros
    // need ≥50 steps (the golden-run test pins ~15-30% block sparsity at
    // step 50); 60 leaves margin without leaving "a few steps" territory
    for _ in 0..60 {
        let b = batcher.next_batch().unwrap();
        be.train_step(&mut state, &b.x, &b.y, &[0.2, 0.1]).unwrap();
    }

    // export → save → load: the artifact round-trips bit-exactly
    let model = infer::export(&be, &state).unwrap();
    assert_eq!(model.layers.len(), 3);
    assert_eq!((model.in_dim, model.out_dim), (784, 10));
    assert_eq!(model.layers[0].m2, 8);
    assert_eq!(model.layers[0].n2, 16);
    assert!(
        model.layers.iter().any(|l| l.occupancy() < 1.0),
        "training at λ=0.2 must produce at least one pruned block (occupancies {:?})",
        model.layers.iter().map(|l| l.occupancy()).collect::<Vec<_>>()
    );
    let dir = std::env::temp_dir().join("bs_infer_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t2.bsm");
    model.save(&path).unwrap();
    let model = BsrModel::load(&path).unwrap();

    // held-out batch + reference logits through the materialized dense
    // chain (what eval_step's factorized forward equals within 1e-4)
    let nb = 32usize;
    let idx: Vec<usize> = (0..nb).collect();
    let batch = assemble_batch(&test, &idx).unwrap();
    let xs = batch.x.as_f32().unwrap().data().to_vec();
    let ys = batch.y.i32_data().unwrap().to_vec();
    let ws = be.materialize(&state).unwrap();
    let mut reference = xs.clone();
    let mut feat = 784usize;
    for (li, (_, w)) in ws.iter().enumerate() {
        let m = w.shape()[0];
        reference = linalg::matmul_nt(&reference, w.data(), nb, feat, m);
        if li + 1 < ws.len() {
            linalg::relu_inplace(&mut reference);
        }
        feat = m;
    }

    // serve every example through the engine from concurrent clients
    let engine =
        Engine::new(model, EngineOpts { max_batch: 8, workers: 2, queue_depth: 64 }).unwrap();
    let served: Vec<(usize, Vec<f32>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|c| {
                let (engine, xs) = (&engine, &xs);
                s.spawn(move || {
                    (0..nb)
                        .filter(|i| i % 4 == c)
                        .map(|i| {
                            let p = engine.predict(&xs[i * 784..(i + 1) * 784]).unwrap();
                            assert!(p.batch_size >= 1 && p.batch_size <= 8);
                            (i, p.logits)
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(served.len(), nb);
    let mut logits = vec![0.0f32; nb * 10];
    for (i, row) in served {
        logits[i * 10..(i + 1) * 10].copy_from_slice(&row);
    }
    let max_diff = logits
        .iter()
        .zip(&reference)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "served logits drifted from the trained model: {max_diff}");

    // and the engine's CE on this batch equals eval_step's within 1e-4
    let eval = be.eval_step(&state, &batch.x, &batch.y).unwrap();
    let sm = linalg::softmax_ce(&logits, &ys, nb, 10).unwrap();
    assert!(
        (sm.ce_mean - eval[0]).abs() < 1e-4,
        "engine CE {} vs eval_step CE {}",
        sm.ce_mean,
        eval[0]
    );
    // a knife-edge argmax tie could flip one row across the two float
    // summation orders; more than that means a real mismatch
    assert!(
        (sm.correct - eval[1]).abs() <= 1.0,
        "engine correct {} vs eval_step correct {}",
        sm.correct,
        eval[1]
    );
}

/// RigL export: the packed occupancy must equal the mask density exactly,
/// and the BSR forward must match the training backend's masked matmul.
#[test]
fn rigl_export_preserves_mask_structure() {
    let be = NativeBackend::with_default_specs();
    let state = be.init_state("t1_rigl_b2x2", 3).unwrap();
    let mask = state.param("fc.mask").unwrap().clone();
    let density = mask.data().iter().sum::<f32>() as f64 / mask.len() as f64;
    let model = infer::export(&be, &state).unwrap();
    assert_eq!(model.layers.len(), 1);
    let l = &model.layers[0];
    assert_eq!((l.m, l.n, l.m2, l.n2), (10, 784, 2, 2));
    assert!(
        (l.occupancy() - density).abs() < 1e-12,
        "occupancy {} vs mask density {density}",
        l.occupancy()
    );
    assert!(l.infer_flops() < l.dense_flops());

    let nb = 4usize;
    let mut rngx = blocksparse::util::rng::Rng::new(9);
    let x: Vec<f32> = (0..nb * 784).map(|_| rngx.normal()).collect();
    let w = state.param("fc.W").unwrap();
    let want = linalg::block_sparse_matmul_nt(&x, w.data(), mask.data(), nb, 10, 784, 2, 2)
        .unwrap();
    let got = bsr::model_forward(&model, &x, nb).unwrap();
    let diff = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(diff < 1e-4, "BSR forward drifted from the masked matmul: {diff}");
}

/// Iterative-pruning export packs at 1×1 (element CSR): the stored-value
/// fraction is exactly the keep rate the pruning controller enforced.
#[test]
fn prune_export_is_element_level() {
    let be = NativeBackend::with_default_specs();
    let mut state = be.init_state("t1_prune", 0).unwrap();
    be.prune(&mut state, 0.6).unwrap();
    let model = infer::export(&be, &state).unwrap();
    let l = &model.layers[0];
    assert_eq!((l.m2, l.n2), (1, 1), "prune specs declare no block shape");
    assert!(
        (l.occupancy() - 0.4).abs() < 1e-3,
        "occupancy {} vs 40% keep rate",
        l.occupancy()
    );
    assert_eq!(model.nnz_params(), l.nnz_blocks() as u64);
}
