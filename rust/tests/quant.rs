//! Int8 quantization property tests (ISSUE-10 satellite 3): the
//! per-block-row symmetric scheme's round-trip bound, its degenerate
//! shapes (all-zero blocks, 1×1 blocks), and the end-to-end fidelity gate
//! on a really-trained Table-2 artifact — f32 logits vs int8 logits must
//! stay within the same MAE bound `BENCH_infer.json` enforces.

use blocksparse::backend::native::NativeBackend;
use blocksparse::backend::Backend;
use blocksparse::coordinator::dataset_for;
use blocksparse::data::{assemble_batch, Batcher};
use blocksparse::infer::bsr::{bsr_forward, model_forward};
use blocksparse::infer::mmap::open_quant_mmap;
use blocksparse::infer::quant::{
    dequantize_layer, model_forward_q8, q8_forward, quantize_layer, quantize_model, QuantModel,
};
use blocksparse::infer::{self, load_auto, BsrLayer, BsrModel};
use blocksparse::util::rng::Rng;

fn dense(m: usize, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..m * n)
        .map(|i| if (i / 3) % 4 == 0 { 0.0 } else { rng.normal() })
        .collect()
}

/// `q = clamp(round(w/scale), ±127)` with `scale = max|row|/127` keeps
/// every weight within half a quantization step of its reconstruction:
/// |w − scale·q| ≤ scale/2. Checked element-wise over every stored block
/// row of a spread of block shapes.
#[test]
fn round_trip_error_is_bounded_by_half_a_scale() {
    for (m, n, m2, n2, seed) in [
        (12, 20, 2, 2, 1u64),
        (16, 24, 4, 8, 2),
        (8, 16, 8, 4, 3),
        (10, 14, 2, 7, 4),
    ] {
        let l = BsrLayer::from_dense("rt", &dense(m, n, seed), m, n, m2, n2).unwrap();
        let q = quantize_layer(&l);
        q.validate().unwrap();
        let dq = dequantize_layer(&q);
        dq.validate().unwrap();
        let (orig, back) = (l.blocks.as_slice(), dq.blocks.as_slice());
        let (qs, scales) = (q.qblocks.as_slice(), q.scales.as_slice());
        let bs = m2 * n2;
        let mut saw_scale = false;
        for k in 0..l.nnz_blocks() {
            for i2 in 0..m2 {
                let s = scales[k * m2 + i2];
                assert!(s.is_finite() && s >= 0.0, "scale {s}");
                saw_scale |= s > 0.0;
                for j2 in 0..n2 {
                    let idx = k * bs + i2 * n2 + j2;
                    // the symmetric range never uses −128
                    assert!(qs[idx] >= -127, "q={} at {idx}", qs[idx]);
                    let err = (orig[idx] - back[idx]).abs();
                    assert!(
                        err <= s * 0.5 + 1e-7,
                        "({m}x{n})/({m2}x{n2}) block {k} row {i2} col {j2}: \
                         |{} - {}| = {err} > scale/2 = {}",
                        orig[idx],
                        back[idx],
                        s * 0.5
                    );
                }
                // a zero scale must mean a genuinely all-zero row
                if s == 0.0 {
                    let row = &orig[k * bs + i2 * n2..k * bs + (i2 + 1) * n2];
                    assert!(row.iter().all(|&v| v == 0.0), "zero scale over {row:?}");
                }
            }
        }
        assert!(saw_scale, "fixture must contain non-zero rows");
    }
}

/// The degenerate shapes: an explicitly stored all-zero block must
/// quantize to scale 0 / q 0 and round-trip exactly; 1×1 blocks put each
/// weight at full scale (q = ±127), so reconstruction is exact up to one
/// f32 rounding of `(w/127)·127`.
#[test]
fn zero_blocks_and_single_element_blocks_round_trip() {
    // hand-built layer: block (0,0) is stored but all-zero, block (1,1)
    // carries values — from_dense would have dropped the zero block, and
    // a corrupt-tolerant loader may hand the kernels exactly this shape
    let l = BsrLayer {
        name: "edge".into(),
        m: 4,
        n: 4,
        m2: 2,
        n2: 2,
        row_ptr: vec![0, 1, 2],
        col_idx: vec![0, 1],
        blocks: vec![0.0, 0.0, 0.0, 0.0, 1.5, -2.0, 0.25, 3.0].into(),
    };
    l.validate().unwrap();
    let q = quantize_layer(&l);
    q.validate().unwrap();
    assert_eq!(&q.scales.as_slice()[..2], &[0.0, 0.0], "all-zero rows must get scale 0");
    assert_eq!(&q.qblocks.as_slice()[..4], &[0i8; 4]);
    let dq = dequantize_layer(&q);
    assert_eq!(&dq.blocks.as_slice()[..4], &[0.0f32; 4], "zero block round-trips exactly");

    // the zero block contributes exactly zero through the int8 kernel too
    let x = vec![1.0f32; 4];
    let zq = q8_forward(&x, 1, &q).unwrap();
    let zf = bsr_forward(&x, 1, &dq).unwrap();
    assert_eq!(zq[0], 0.0, "output row fed only by the zero block");
    assert_eq!(zq[1], 0.0);
    for (a, b) in zq.iter().zip(&zf) {
        assert!((a - b).abs() <= 1e-5, "int8 vs dequantized forward: {a} vs {b}");
    }

    // 1×1 blocks: every stored weight is its own block row at full scale
    let l1 = BsrLayer::from_dense("one", &dense(6, 10, 9), 6, 10, 1, 1).unwrap();
    let q1 = quantize_layer(&l1);
    let dq1 = dequantize_layer(&q1);
    for (w, b) in l1.blocks.as_slice().iter().zip(dq1.blocks.as_slice()) {
        assert!(
            (w - b).abs() <= w.abs() * 1e-5,
            "1x1 quantization must be (near-)exact: {w} vs {b}"
        );
    }
    assert!(q1.qblocks.as_slice().iter().all(|&v| v == 0 || v.abs() == 127));
}

/// The fidelity gate on real weights: train `t2_kpd_16x8_8x4_4x2` the
/// same way the export round-trip test does, quantize the export, and
/// hold int8 logits to the bench's bound — MAE ≤ 5% of the f32 logit RMS
/// (+1e-3 for near-zero logit scales).
#[test]
fn trained_t2_export_quantizes_within_the_mae_gate() {
    let be = NativeBackend::with_default_specs();
    let spec_key = "t2_kpd_16x8_8x4_4x2";
    let spec = be.spec(spec_key).unwrap().clone();
    let (train, test) = dataset_for(&spec, 7, 512, 128).unwrap();
    let mut state = be.init_state(spec_key, 0).unwrap();
    let mut batcher = Batcher::new(&train, spec.batch, 1, true);
    for _ in 0..60 {
        let b = batcher.next_batch().unwrap();
        be.train_step(&mut state, &b.x, &b.y, &[0.2, 0.1]).unwrap();
    }
    let model = infer::export(&be, &state).unwrap();
    let q = quantize_model(&model).unwrap();
    assert_eq!((q.in_dim, q.out_dim), (784, 10));
    assert_eq!(q.block_sparsity(), model.block_sparsity(), "quantization keeps the structure");
    assert_eq!(q.nnz_params(), model.nnz_params());

    let nb = 64usize;
    let idx: Vec<usize> = (0..nb).collect();
    let batch = assemble_batch(&test, &idx).unwrap();
    let xs = batch.x.as_f32().unwrap().data().to_vec();
    let zf = model_forward(&model, &xs, nb).unwrap();
    let zq = model_forward_q8(&q, &xs, nb).unwrap();
    assert_eq!(zf.len(), zq.len());
    let mae = zf
        .iter()
        .zip(&zq)
        .map(|(a, b)| (a - b).abs() as f64)
        .sum::<f64>()
        / zf.len() as f64;
    let rms = (zf.iter().map(|v| (v * v) as f64).sum::<f64>() / zf.len() as f64).sqrt();
    let bound = 0.05 * rms + 1e-3;
    assert!(
        mae <= bound,
        "int8 logits drifted: MAE {mae:.6} > bound {bound:.6} (f32 RMS {rms:.4})"
    );
    // int8 must also preserve most decisions on this batch
    let agree = (0..nb)
        .filter(|&i| {
            let row = |z: &[f32]| {
                z[i * 10..(i + 1) * 10]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(c, _)| c)
            };
            row(&zf) == row(&zq)
        })
        .count();
    assert!(agree * 10 >= nb * 9, "argmax agreement {agree}/{nb} below 90%");
}

/// An int8 artifact is one artifact: save → load round-trips the exact
/// values, the mmap open serves bit-identical logits to the read open,
/// and `load_auto` routes it to the int8 engine path by dtype.
#[test]
fn int8_artifact_round_trips_and_serves_identically_mapped_or_read() {
    let model = BsrModel {
        spec: "q8rt".into(),
        method: "kpd".into(),
        in_dim: 16,
        out_dim: 6,
        layers: vec![
            BsrLayer::from_dense("fc1", &dense(12, 16, 21), 12, 16, 2, 2).unwrap(),
            BsrLayer::from_dense("fc2", &dense(6, 12, 22), 6, 12, 2, 2).unwrap(),
        ],
    };
    let q = quantize_model(&model).unwrap();
    let dir = std::env::temp_dir().join("bs_quant_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("q.bsm");
    q.save(&path).unwrap();

    let read = QuantModel::load(&path).unwrap();
    assert_eq!(read, q);
    let (mapped, stats) = open_quant_mmap(&path).unwrap();
    assert_eq!(stats.file_bytes, std::fs::metadata(&path).unwrap().len());

    let mut rng = Rng::new(0xF1DE);
    let x: Vec<f32> = (0..4 * 16).map(|_| rng.normal()).collect();
    let z_read = model_forward_q8(&read, &x, 4).unwrap();
    let z_mapped = model_forward_q8(&mapped, &x, 4).unwrap();
    assert_eq!(z_read, z_mapped, "mapped and read opens must serve identical logits");

    let served = load_auto(&path).unwrap();
    assert_eq!(served.dtype(), "int8");
    assert_eq!(served.forward(&x, 4).unwrap(), z_read);
}
