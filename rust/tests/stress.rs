//! Engine concurrency stress layer (ISSUE-10 satellite 2): mixed
//! blocking + async clients racing hot swaps and shutdown.
//!
//! The three invariants these tests hammer:
//!
//! * **accounting** — after everything drains, `accepted == completed +
//!   failed` and nothing is double-counted or lost, no matter how the
//!   shutdown interleaves with in-flight work;
//! * **generation purity** — every response's `generation` maps to a
//!   model that was actually deployed at that generation, and its logits
//!   are bit-identical to that model's own forward (a batch never mixes
//!   weights across a swap, including f32 → int8 swaps);
//! * **bounded threads** — an N-deep async window costs N queue slots,
//!   not N parked OS threads (`/proc` accounting, linux only).
//!
//! The tests in this binary serialize on a process-wide gate: the thread
//! accounting below counts every thread in the process, so the mixed-
//! client test (which spawns a dozen scoped clients) must not overlap it.

use blocksparse::infer::engine::{
    drive_async, Engine, EngineError, EngineOpts, Prediction, PredictionHandle,
};
use blocksparse::infer::quant::quantize_model;
use blocksparse::infer::{BsrLayer, BsrModel, ServedModel};
use blocksparse::util::rng::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Tests in this file must not overlap (see module doc).
static GATE: Mutex<()> = Mutex::new(());

fn serialized() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// A dense-ish 16 → 12 → 6 two-layer stack with 2×2 blocks; different
/// seeds give different weights over the identical shape, so every
/// variant is hot-swappable over every other.
fn model(seed: u64) -> BsrModel {
    let mut rng = Rng::new(seed);
    let mut dense = |m: usize, n: usize| -> Vec<f32> {
        (0..m * n)
            .map(|i| if (i / 4) % 5 == 0 { 0.0 } else { rng.normal() })
            .collect()
    };
    let w1 = dense(12, 16);
    let w2 = dense(6, 12);
    BsrModel {
        spec: "stress".into(),
        method: "kpd".into(),
        in_dim: 16,
        out_dim: 6,
        layers: vec![
            BsrLayer::from_dense("fc1", &w1, 12, 16, 2, 2).unwrap(),
            BsrLayer::from_dense("fc2", &w2, 6, 12, 2, 2).unwrap(),
        ],
    }
}

fn opts(max_batch: usize, workers: usize, queue_depth: usize) -> EngineOpts {
    EngineOpts { max_batch, workers, queue_depth }
}

/// Wait out a client's outstanding async handles. Admitted work always
/// resolves — even when the shutdown lands before its batch runs.
fn drain_pending(
    served: &AtomicUsize,
    pending: &mut Vec<(Vec<f32>, PredictionHandle)>,
    mine: &mut Vec<(Vec<f32>, Prediction)>,
) {
    for (x, h) in pending.drain(..) {
        let p = h.wait().expect("admitted async request lost");
        served.fetch_add(1, Ordering::Relaxed);
        mine.push((x, p));
    }
}

#[cfg(target_os = "linux")]
fn proc_thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").expect("/proc/self/status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .expect("Threads: line in /proc/self/status")
}

/// 16 clients — even ones blocking `predict`, odd ones windowed
/// `predict_async` — race a swap storm (f32 and int8 variants) and a
/// shutdown that fires mid-traffic. Every response must be provably from
/// one deployed model, and the engine's books must balance after the
/// drain.
#[test]
fn mixed_clients_race_swaps_and_shutdown_without_losing_anything() {
    let _gate = serialized();
    const CLIENTS: usize = 16;
    const BUDGET: usize = 60;
    const SWAPS: usize = 24;

    // variant 3 is variant 0 quantized: the swap storm crosses dtypes
    let variants: Vec<ServedModel> = vec![
        model(0xA).into(),
        model(0xB).into(),
        model(0xC).into(),
        quantize_model(&model(0xA)).unwrap().into(),
    ];
    let engine = Engine::new(variants[0].clone(), opts(4, 2, 64)).unwrap();
    let gen_of: Mutex<HashMap<u64, usize>> = Mutex::new(HashMap::from([(0u64, 0usize)]));
    let served = AtomicUsize::new(0); // completed requests, all clients
    let shed = AtomicUsize::new(0);
    let clients_done = AtomicUsize::new(0);

    let got: Vec<(Vec<f32>, Prediction)> = std::thread::scope(|s| {
        // the swap storm: cycle the variants, pacing on served traffic so
        // swaps land between (and inside) client bursts; the clients_done
        // exit keeps the pacing loop finite no matter how traffic lands
        s.spawn(|| {
            for i in 1..=SWAPS {
                let v = i % variants.len();
                let g = engine
                    .swap_model(variants[v].clone())
                    .unwrap_or_else(|e| panic!("swap {i} rejected: {e}"));
                gen_of.lock().unwrap().insert(g, v);
                while served.load(Ordering::Relaxed) < i * 8
                    && clients_done.load(Ordering::Relaxed) < CLIENTS
                {
                    std::thread::yield_now();
                }
            }
        });

        // the shutdown racer: pull the plug while clients are mid-flight
        s.spawn(|| {
            while served.load(Ordering::Relaxed) < CLIENTS * BUDGET / 2
                && clients_done.load(Ordering::Relaxed) < CLIENTS
            {
                std::thread::yield_now();
            }
            engine.shutdown();
        });

        let clients: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let served = &served;
                let shed = &shed;
                let clients_done = &clients_done;
                let engine = &engine;
                s.spawn(move || {
                    let mut rng = Rng::new(0x57E55 ^ ((c as u64) << 8));
                    let mut mine: Vec<(Vec<f32>, Prediction)> = Vec::new();
                    let mut pending: Vec<(Vec<f32>, PredictionHandle)> = Vec::new();
                    for _ in 0..BUDGET {
                        let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
                        if c % 2 == 0 {
                            match engine.predict(&x) {
                                Ok(p) => {
                                    served.fetch_add(1, Ordering::Relaxed);
                                    mine.push((x, p));
                                }
                                Err(EngineError::Overloaded { .. }) => {
                                    shed.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(EngineError::ShutDown) => break,
                                Err(e) => panic!("client {c}: {e}"),
                            }
                        } else {
                            match engine.predict_async(&x) {
                                Ok(h) => {
                                    pending.push((x, h));
                                    if pending.len() >= 4 {
                                        drain_pending(served, &mut pending, &mut mine);
                                    }
                                }
                                Err(EngineError::Overloaded { .. }) => {
                                    shed.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(EngineError::ShutDown) => break,
                                Err(e) => panic!("client {c}: {e}"),
                            }
                        }
                    }
                    drain_pending(served, &mut pending, &mut mine);
                    clients_done.fetch_add(1, Ordering::Relaxed);
                    mine
                })
            })
            .collect();
        clients
            .into_iter()
            .flat_map(|h| h.join().expect("client panicked"))
            .collect()
    });

    // the books balance: nothing admitted went missing, nothing failed
    let stats = engine.stats();
    assert_eq!(stats.failed, 0, "no batch may fail in this storm");
    assert_eq!(stats.accepted, stats.completed + stats.failed);
    assert_eq!(stats.completed as usize, got.len(), "every completion reached a client");
    assert_eq!(stats.shed as usize, shed.load(Ordering::Relaxed));
    assert!(!got.is_empty(), "the storm must serve real traffic");

    // generation purity: each response is bit-identical to the forward of
    // the model deployed at its generation — across dtype swaps too
    let gen_of = gen_of.into_inner().unwrap();
    for (x, p) in &got {
        let v = *gen_of
            .get(&p.generation)
            .unwrap_or_else(|| panic!("generation {} was never deployed", p.generation));
        let expect = variants[v].forward(x, 1).unwrap();
        assert_eq!(p.logits, expect, "generation {} (variant {v}) logits drifted", p.generation);
    }
    // the storm must actually have crossed generations
    let gens: std::collections::HashSet<u64> = got.iter().map(|(_, p)| p.generation).collect();
    assert!(gens.len() > 1, "swap storm never landed mid-traffic: {gens:?}");
}

/// The tentpole thread claim, measured: a 4×-capacity async window (and a
/// 16×-capacity offered load) may grow the process by dispatcher + worker
/// threads — never by anything proportional to the window. The blocking
/// driver needs a thread per in-flight request to create this load shape;
/// `drive_async` holds the whole window on one thread.
#[cfg(target_os = "linux")]
#[test]
fn async_overload_window_never_costs_a_thread_per_request() {
    let _gate = serialized();
    let before = proc_thread_count();

    let workers = 2usize;
    let engine = Engine::new(model(0xD), opts(4, workers, 8)).unwrap();
    let window = 4 * engine.capacity();
    let requests = 16 * engine.capacity();
    assert!(window >= 64, "window {window} too small to prove anything");

    // sample the peak thread count while the drive is in flight
    let (report, peak) = std::thread::scope(|s| {
        let done = std::sync::atomic::AtomicBool::new(false);
        let done_ref = &done;
        let sampler = s.spawn(move || {
            let mut peak = 0usize;
            while !done_ref.load(Ordering::Acquire) {
                peak = peak.max(proc_thread_count());
                std::thread::yield_now();
            }
            peak
        });
        let report = drive_async(&engine, requests, window, 0x5712E55).unwrap();
        done.store(true, Ordering::Release);
        (report, sampler.join().unwrap())
    });

    // every request is accounted for, and the engine books agree
    assert_eq!(report.offered, requests);
    assert_eq!(report.accepted + report.shed, report.offered);
    let stats = engine.stats();
    assert_eq!(stats.accepted, report.accepted as u64);
    assert_eq!(stats.shed, report.shed as u64);
    assert_eq!(stats.accepted, stats.completed + stats.failed);
    assert_eq!(stats.failed, 0);

    // the bound: workers + dispatcher + the sampler itself + harness
    // slack — a constant, nowhere near the 64+ handle window
    let bound = before + workers + 6;
    assert!(
        peak <= bound,
        "async drive grew the process to {peak} threads (started at {before}, \
         window {window}) — the window must not cost threads"
    );
    assert!(peak < before + window / 2, "thread growth scales with the window");
}

/// Lost-waiter focus: handles admitted immediately before (and during)
/// `shutdown` must all resolve — a waiter parked on a slot the dispatcher
/// never completes would hang this test forever.
#[test]
fn shutdown_never_strands_an_admitted_handle() {
    let _gate = serialized();
    for round in 0..20u64 {
        let engine = Engine::new(model(round), opts(4, 1, 32)).unwrap();
        let mut rng = Rng::new(round ^ 0xF1A6);
        let handles: Vec<_> = std::thread::scope(|s| {
            let engine_ref = &engine;
            // shutdown fires from a sibling thread with no coordination:
            // some admissions land before it, some after
            s.spawn(move || {
                std::thread::yield_now();
                engine_ref.shutdown();
            });
            let mut hs = Vec::new();
            for _ in 0..24 {
                let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
                match engine.predict_async(&x) {
                    Ok(h) => hs.push(h),
                    Err(EngineError::ShutDown) | Err(EngineError::Overloaded { .. }) => {}
                    Err(e) => panic!("round {round}: {e}"),
                }
            }
            hs
        });
        let admitted = handles.len();
        for h in handles {
            let p = h.wait().unwrap_or_else(|e| panic!("round {round}: admitted handle lost: {e}"));
            assert_eq!(p.logits.len(), 6);
        }
        let stats = engine.stats();
        assert_eq!(stats.accepted, admitted as u64);
        assert_eq!(stats.completed, admitted as u64);
    }
}
