//! Property-based tests over the pure substrates (mini-prop framework).
//!
//! Invariants: Kronecker algebra, KPD reconstruction vs block structure,
//! Eq. 5 optimality, FLOPs formula consistency, sparsity measurement,
//! config/json round-trips, batcher coverage, checkpoint round-trip.

use blocksparse::backend::native::{layers, linalg, transformer, NativeBackend, SpecConfig};
use blocksparse::backend::Backend;
use blocksparse::blockopt;
use blocksparse::checkpoint::Checkpoint;
use blocksparse::config::Config;
use blocksparse::data::{Batcher, Dataset};
use blocksparse::flops::{self, KpdDims};
use blocksparse::prop_assert;
use blocksparse::sparsity;
use blocksparse::tensor::Tensor;
use blocksparse::testutil::{close, prop_check};
use blocksparse::util::json::Json;

#[test]
fn prop_kron_dimensions_and_values() {
    prop_check("kron dims", 100, |g| {
        let (m1, n1) = (g.usize_in(1, 5), g.usize_in(1, 5));
        let (m2, n2) = (g.usize_in(1, 5), g.usize_in(1, 5));
        let a = Tensor::new(&[m1, n1], g.normal_vec(m1 * n1)).unwrap();
        let b = Tensor::new(&[m2, n2], g.normal_vec(m2 * n2)).unwrap();
        let k = a.kron(&b).unwrap();
        prop_assert!(k.shape() == [m1 * m2, n1 * n2], "shape {:?}", k.shape());
        // spot-check a random entry
        let (i1, j1) = (g.usize_in(0, m1 - 1), g.usize_in(0, n1 - 1));
        let (i2, j2) = (g.usize_in(0, m2 - 1), g.usize_in(0, n2 - 1));
        let want = a.at2(i1, j1) * b.at2(i2, j2);
        let got = k.at2(i1 * m2 + i2, j1 * n2 + j2);
        prop_assert!(close(got, want, 1e-6, 1e-5), "{got} != {want}");
        Ok(())
    });
}

#[test]
fn prop_kpd_zero_s_entry_zeroes_whole_block() {
    prop_check("kpd zero block", 60, |g| {
        let (m1, n1) = (g.usize_in(1, 4), g.usize_in(1, 4));
        let (m2, n2) = (g.usize_in(1, 4), g.usize_in(1, 4));
        let r = g.usize_in(1, 3);
        let mut s = Tensor::new(&[m1, n1], g.uniform_vec(m1 * n1, 0.5, 1.5)).unwrap();
        let (zi, zj) = (g.usize_in(0, m1 - 1), g.usize_in(0, n1 - 1));
        s.set2(zi, zj, 0.0);
        let a = Tensor::new(&[r, m1, n1], g.normal_vec(r * m1 * n1)).unwrap();
        let b = Tensor::new(&[r, m2, n2], g.normal_vec(r * m2 * n2)).unwrap();
        let w = Tensor::kpd_reconstruct(&s, &a, &b).unwrap();
        for i in 0..m2 {
            for j in 0..n2 {
                let v = w.at2(zi * m2 + i, zj * n2 + j);
                prop_assert!(v == 0.0, "block ({zi},{zj}) leaked {v}");
            }
        }
        // and block sparsity sees at least that one zero block
        let rate = sparsity::block_sparsity(&w, m2, n2, 0.001).unwrap();
        prop_assert!(rate >= 1.0 / (m1 * n1) as f64 - 1e-9, "rate {rate}");
        Ok(())
    });
}

#[test]
fn prop_eq5_bnb_is_optimal() {
    prop_check("eq5 optimal", 80, |g| {
        let m = g.usize_in(1, 300);
        let n = g.usize_in(1, 300);
        let r = g.usize_in(1, 4);
        let d = blockopt::optimal_block(m, n, r).map_err(|e| e.to_string())?;
        let best = blockopt::optimal_block_brute(m, n, r).map_err(|e| e.to_string())?;
        prop_assert!(
            blockopt::eq5_cost_r(d.m1, d.n1, d.m2, d.n2, r) == best,
            "bnb {} != brute {best} at ({m},{n}) r={r}",
            blockopt::eq5_cost_r(d.m1, d.n1, d.m2, d.n2, r)
        );
        prop_assert!(d.m1 * d.m2 == m && d.n1 * d.n2 == n, "factorization broken");
        prop_assert!(d.r == r, "rank not carried through");
        Ok(())
    });
}

#[test]
fn prop_kpd_flops_below_dense_when_blocks_are_large() {
    // Prop. 2's point: with n2 ≫ and small r, factorized training is
    // cheaper; verify over random shapes with r=1 and n2 ≥ 8.
    prop_check("kpd flops win", 60, |g| {
        let m1 = g.usize_in(1, 8);
        let m2 = g.usize_in(1, 4);
        let n1 = g.usize_in(1, 8);
        let n2 = 8 * g.usize_in(1, 8);
        let d = KpdDims { m1, n1, m2, n2, r: 1 };
        let nb = 64;
        let dense = flops::dense_step_flops(nb, (m1 * m2) as u64, (n1 * n2) as u64);
        let kpd = flops::kpd_step_flops(nb, d);
        // win requires the (S⊙A) contraction not to dominate (n1 small)
        // and the matrix large enough that constant terms don't (Prop. 2
        // is an asymptotic statement)
        if n1 <= 4 && m1 * n1 >= 2 && d.m() * d.n() >= 512 {
            prop_assert!(kpd < dense, "kpd {kpd} !< dense {dense} at {d:?}");
        }
        prop_assert!(d.train_params() <= d.m() as u64 * d.n() as u64,
                     "more params than dense");
        Ok(())
    });
}

#[test]
fn prop_flops_linear_in_batch() {
    prop_check("flops linear in N", 60, |g| {
        let d = KpdDims {
            m1: g.usize_in(1, 6), n1: g.usize_in(1, 6),
            m2: g.usize_in(1, 6), n2: g.usize_in(1, 6),
            r: g.usize_in(1, 4),
        };
        let f1 = flops::kpd_forward_flops(100, d) as f64;
        let f2 = flops::kpd_forward_flops(200, d) as f64;
        prop_assert!((f2 / f1) < 2.05 && (f2 / f1) > 1.8, "ratio {}", f2 / f1);
        Ok(())
    });
}

#[test]
fn prop_mask_sparsity_counts() {
    prop_check("mask sparsity", 60, |g| {
        let n = g.usize_in(1, 200);
        let zeros = g.usize_in(0, n);
        let mut data = vec![1.0f32; n];
        for v in data.iter_mut().take(zeros) {
            *v = 0.0;
        }
        let t = Tensor::new(&[n], data).unwrap();
        let got = sparsity::mask_sparsity(&t);
        prop_assert!(close(got as f32, zeros as f32 / n as f32, 1e-6, 0.0),
                     "{got} vs {}/{}", zeros, n);
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    prop_check("json roundtrip", 60, |g| {
        // build a random nested value
        let mut obj = std::collections::BTreeMap::new();
        for i in 0..g.usize_in(0, 6) {
            let v = match g.usize_in(0, 3) {
                0 => Json::Num(g.f32_in(-1e6, 1e6) as f64),
                1 => Json::Str(format!("s{}\"quote\n", g.usize_in(0, 99))),
                2 => Json::Bool(g.bool()),
                _ => Json::Arr(vec![Json::Num(i as f64), Json::Null]),
            };
            obj.insert(format!("k{i}"), v);
        }
        let j = Json::Obj(obj);
        let text = j.to_string_pretty();
        let back = Json::parse(&text).map_err(|e| e.to_string())?;
        // numeric equality modulo f64 formatting
        prop_assert!(format!("{back:?}") == format!("{j:?}"),
                     "roundtrip mismatch:\n{j:?}\n{back:?}");
        Ok(())
    });
}

#[test]
fn prop_config_roundtrip_scalars() {
    prop_check("config parse", 60, |g| {
        let i = g.usize_in(0, 10_000) as i64;
        let f = g.f32_in(-100.0, 100.0);
        let text = format!("[a]\nx = {i}\ny = {f}\nz = \"v{i}\"\nw = [1, 2, 3]\n");
        let cfg = Config::parse(&text).map_err(|e| e.to_string())?;
        prop_assert!(cfg.usize_or("a.x", 9999) as i64 == i, "int");
        prop_assert!(close(cfg.f64_or("a.y", 0.0) as f32, f, 1e-3, 1e-3), "float");
        prop_assert!(cfg.str_or("a.z", "") == format!("v{i}"), "str");
        Ok(())
    });
}

#[test]
fn prop_batcher_epoch_is_permutation() {
    prop_check("batcher coverage", 40, |g| {
        let n = g.usize_in(4, 64);
        let batch = g.usize_in(1, n);
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let y: Vec<i32> = vec![0; n];
        let d = Dataset::from_images(1, 2, x, y).unwrap();
        let mut b = Batcher::new(&d, batch, g.usize_in(0, 1000) as u64, true);
        let mut seen = vec![0usize; n];
        for _ in 0..b.batches_per_epoch() {
            let bt = b.next_batch().map_err(|e| e.to_string())?;
            let xs = bt.x.as_f32().map_err(|e| e.to_string())?;
            for &v in xs.data() {
                seen[v as usize] += 1;
            }
        }
        prop_assert!(seen.iter().all(|&c| c <= 1), "repeat within epoch: {seen:?}");
        let covered: usize = seen.iter().sum();
        prop_assert!(covered == (n / batch) * batch, "covered {covered}");
        Ok(())
    });
}

#[test]
fn prop_checkpoint_roundtrip() {
    prop_check("checkpoint roundtrip", 30, |g| {
        let dir = std::env::temp_dir().join("bs_prop_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("c{}.bsck", g.case));
        let k = g.usize_in(1, 5);
        let entries: Vec<(String, Tensor)> = (0..k)
            .map(|i| {
                let rows = g.usize_in(1, 8);
                let cols = g.usize_in(1, 8);
                (format!("t{i}"),
                 Tensor::new(&[rows, cols], g.normal_vec(rows * cols)).unwrap())
            })
            .collect();
        Checkpoint::new(entries.clone()).save(&path).map_err(|e| e.to_string())?;
        let back = Checkpoint::load(&path).map_err(|e| e.to_string())?;
        prop_assert!(back.entries.len() == k, "count");
        for (name, t) in &entries {
            let bt = back.get(name).ok_or("missing entry")?;
            prop_assert!(bt.shape() == t.shape(), "shape");
            prop_assert!(bt.max_abs_diff(t) == 0.0, "data");
        }
        Ok(())
    });
}

/// Central-finite-difference check of the multi-layer KPD backward chain:
/// dS/dA/dB of *every* slot of a 3-layer MLP — including both hidden
/// layers, whose gradients flow through the ReLU and through
/// `kpd::backward_dx`'s input-gradient chaining — must match central
/// differences of the CE loss.
///
/// ReLU makes the loss piecewise-smooth: a parameter whose perturbation
/// flips an activation sign has no meaningful finite difference at this h.
/// Each entry is therefore probed at h and 2h first; entries where the two
/// estimates disagree (a kink or strong curvature in the bracket) are
/// skipped, and the property additionally requires that ≥ 70% of entries
/// were stable — so the skip path cannot silently swallow a broken chain.
#[test]
fn prop_mlp_fd_gradients_both_hidden_layers_through_relu() {
    prop_check("mlp fd gradients", 6, |g| {
        let widths = [12usize, 8, 6, 4];
        let blocks = [
            (*g.pick(&[1usize, 2, 4]), *g.pick(&[2usize, 3, 4])),
            (*g.pick(&[1usize, 2, 3]), *g.pick(&[2usize, 4])),
            (*g.pick(&[1usize, 2]), *g.pick(&[2usize, 3])),
        ];
        let rank = g.usize_in(1, 3);
        let nb = 6usize;
        let cfg = SpecConfig::mlp("fd_mlp", "kpd", &widths, &blocks, rank, nb);
        let be = NativeBackend::from_spec(cfg.clone()).map_err(|e| e.to_string())?;
        let mut state = be.init_state("fd_mlp", g.case as u32).map_err(|e| e.to_string())?;
        let x = g.normal_vec(nb * widths[0]);
        let y: Vec<i32> = (0..nb).map(|i| (i % 4) as i32).collect();

        let ce = |state: &blocksparse::backend::TrainState| -> Result<f32, String> {
            let z = layers::forward_logits(&cfg, state, &x, nb).map_err(|e| e.to_string())?;
            let sm = linalg::softmax_ce(&z, &y, nb, 4).map_err(|e| e.to_string())?;
            Ok(sm.ce_mean)
        };
        let (_, grads) =
            layers::loss_and_grads(&cfg, &state, &x, nb, &y).map_err(|e| e.to_string())?;
        for leaf in ["fc1.S", "fc1.A", "fc1.B", "fc2.S", "fc2.A", "fc2.B", "fc3.S"] {
            prop_assert!(grads.contains_key(leaf), "missing analytic grad for {leaf}");
        }

        let mut checked = 0usize;
        let mut skipped = 0usize;
        for (name, gvec) in &grads {
            let orig = state.param_tensor(name).map_err(|e| e.to_string())?;
            for idx in 0..gvec.len() {
                let mut fd_at = |h: f32| -> Result<f32, String> {
                    let mut tp = orig.clone();
                    tp.data_mut()[idx] += h;
                    state.set_param(name, tp).map_err(|e| e.to_string())?;
                    let lp = ce(&state)?;
                    let mut tm = orig.clone();
                    tm.data_mut()[idx] -= h;
                    state.set_param(name, tm).map_err(|e| e.to_string())?;
                    let lm = ce(&state)?;
                    Ok((lp - lm) / (2.0 * h))
                };
                let fd1 = fd_at(1e-2)?;
                let fd2 = fd_at(2e-2)?;
                state.set_param(name, orig.clone()).map_err(|e| e.to_string())?;
                if (fd1 - fd2).abs() > 0.2 * fd1.abs().max(fd2.abs()).max(5e-3) {
                    skipped += 1; // ReLU kink inside the FD bracket
                    continue;
                }
                let analytic = gvec[idx];
                prop_assert!(
                    (fd1 - analytic).abs() < 2e-2 + 5e-2 * fd1.abs(),
                    "{name}[{idx}]: fd {fd1} vs analytic {analytic} \
                     (widths {widths:?} blocks {blocks:?} r={rank})"
                );
                checked += 1;
            }
        }
        prop_assert!(
            checked * 10 >= (checked + skipped) * 7,
            "too many FD-unstable entries: {checked} checked, {skipped} skipped"
        );
        Ok(())
    });
}

/// Gradient-accumulation linearity (ISSUE-5): `grad_step` on a full batch
/// must equal the size-weighted fixed-order reduction of `grad_step` on
/// its shards — for *random* shard splits, on every leaf of all three MLP
/// slots. Shard gradients are per-example sums, so the reduction is the
/// tree sum followed by one division by N; agreement is to f32
/// re-association tolerance. This is the algebraic fact the data-parallel
/// trainer's bit-exactness rests on.
#[test]
fn prop_grad_step_linear_in_shards_all_mlp_slots() {
    use blocksparse::tensor::HostValue;
    use blocksparse::train::reduce::tree_reduce;
    prop_check("grad shard linearity", 8, |g| {
        let widths = [12usize, 8, 6, 4];
        let blocks = [
            (*g.pick(&[1usize, 2, 4]), *g.pick(&[2usize, 3, 4])),
            (*g.pick(&[1usize, 2]), *g.pick(&[2usize, 4])),
            (*g.pick(&[1usize, 2]), *g.pick(&[2usize, 3])),
        ];
        let rank = g.usize_in(1, 3);
        let nb = g.usize_in(6, 24);
        let cfg = SpecConfig::mlp("lin_mlp", "kpd", &widths, &blocks, rank, nb);
        let be = NativeBackend::from_spec(cfg.clone()).map_err(|e| e.to_string())?;
        let state = be.init_state("lin_mlp", g.case as u32).map_err(|e| e.to_string())?;
        let x = g.normal_vec(nb * widths[0]);
        let y: Vec<i32> = (0..nb).map(|i| (i % 4) as i32).collect();
        let wrap = |lo: usize, hi: usize| -> (HostValue, HostValue) {
            (
                HostValue::F32(
                    Tensor::new(&[hi - lo, widths[0]], x[lo * widths[0]..hi * widths[0]].to_vec())
                        .unwrap(),
                ),
                HostValue::I32 { shape: vec![hi - lo], data: y[lo..hi].to_vec() },
            )
        };

        let (bx, by) = wrap(0, nb);
        let full = be.grad_step(&state, &bx, &by).map_err(|e| e.to_string())?;
        // every slot leaf is present in the flat buffer
        let want_len: usize = be.grad_len("lin_mlp").map_err(|e| e.to_string())?;
        prop_assert!(full.grad_sum.len() == want_len, "layout length");

        // a random split into 1..=nb shards (random cut points)
        let mut cuts = vec![0usize, nb];
        for _ in 0..g.usize_in(0, 4) {
            cuts.push(g.usize_in(1, nb.saturating_sub(1).max(1)));
        }
        cuts.sort_unstable();
        cuts.dedup();
        let mut parts = Vec::new();
        for w in cuts.windows(2) {
            let (sx, sy) = wrap(w[0], w[1]);
            parts.push(be.grad_step(&state, &sx, &sy).map_err(|e| e.to_string())?);
        }
        let reduced = tree_reduce(parts).map_err(|e| e.to_string())?;
        prop_assert!(reduced.examples == full.examples, "example count");
        prop_assert!(
            close(reduced.ce_sum, full.ce_sum, 1e-4, 1e-5),
            "ce_sum {} vs {}",
            reduced.ce_sum,
            full.ce_sum
        );
        prop_assert!(reduced.correct == full.correct, "correct count must be exact");
        let inv = 1.0 / nb as f32;
        for (i, (a, b)) in full.grad_sum.iter().zip(&reduced.grad_sum).enumerate() {
            let (ma, mb) = (a * inv, b * inv);
            prop_assert!(
                close(ma, mb, 1e-5, 1e-4),
                "mean grad[{i}]: full {ma} vs sharded {mb} (splits {cuts:?})"
            );
        }
        Ok(())
    });
}

/// Central-finite-difference check of the transformer backward chain: the
/// analytic gradients of [`transformer::loss_and_grads`] must match
/// central differences of CE(forward_logits) on a tiny two-block encoder.
/// The probed leaves are chosen to drive every new backward primitive:
/// `emb.E`/`emb.P` exercise the embedding scatter, the `ln*` gains/biases
/// and `head.W` exercise the LayerNorm backward (pre-LN and final), and
/// the `q`/`v` S-factors only see loss through the softmax-attention
/// backward. The FD-stability skip rule and ≥ 70% coverage floor are the
/// same as the MLP FD property above (the FFN ReLU contributes kinks).
#[test]
fn prop_transformer_fd_gradients_ln_attention_embedding() {
    prop_check("transformer fd gradients", 3, |g| {
        let (vocab, seq, d, heads, d_ff, depth) = (10usize, 4usize, 8usize, 2usize, 12usize, 2usize);
        let nb = 2usize;
        let cfg = SpecConfig::transformer(
            "fd_tf", "lm_tiny", "kpd", vocab, seq, d, heads, d_ff, depth, 2, 2, 2, nb,
        );
        let be = NativeBackend::from_spec(cfg.clone()).map_err(|e| e.to_string())?;
        let mut state = be.init_state("fd_tf", g.case as u32).map_err(|e| e.to_string())?;
        let toks: Vec<i32> = (0..nb * seq).map(|_| g.usize_in(0, vocab - 1) as i32).collect();
        let y: Vec<i32> = (0..nb * seq).map(|_| g.usize_in(0, vocab - 1) as i32).collect();

        let ce = |state: &blocksparse::backend::TrainState| -> Result<f32, String> {
            let z = transformer::forward_logits(&cfg, state, &toks, nb)
                .map_err(|e| e.to_string())?;
            let sm = linalg::softmax_ce(&z, &y, nb * seq, vocab).map_err(|e| e.to_string())?;
            Ok(sm.ce_mean)
        };
        let (ce0, grads) =
            transformer::loss_and_grads(&cfg, &state, &toks, nb, &y).map_err(|e| e.to_string())?;
        prop_assert!(close(ce0, ce(&state)?, 1e-5, 1e-5), "loss_and_grads CE disagrees");

        let leaves = [
            "emb.E", "emb.P", "b0.ln1.g", "b0.ln1.b", "b1.ln2.g", "lnf.g", "lnf.b",
            "head.W", "b0.q.S", "b0.v.S", "b1.fc1.S",
        ];
        let mut checked = 0usize;
        let mut skipped = 0usize;
        for name in leaves {
            let gvec = grads.get(name).ok_or(format!("missing analytic grad for {name}"))?;
            let orig = state.param_tensor(name).map_err(|e| e.to_string())?;
            for idx in 0..gvec.len() {
                let mut fd_at = |h: f32| -> Result<f32, String> {
                    let mut tp = orig.clone();
                    tp.data_mut()[idx] += h;
                    state.set_param(name, tp).map_err(|e| e.to_string())?;
                    let lp = ce(&state)?;
                    let mut tm = orig.clone();
                    tm.data_mut()[idx] -= h;
                    state.set_param(name, tm).map_err(|e| e.to_string())?;
                    let lm = ce(&state)?;
                    Ok((lp - lm) / (2.0 * h))
                };
                let fd1 = fd_at(1e-2)?;
                let fd2 = fd_at(2e-2)?;
                state.set_param(name, orig.clone()).map_err(|e| e.to_string())?;
                if (fd1 - fd2).abs() > 0.2 * fd1.abs().max(fd2.abs()).max(5e-3) {
                    skipped += 1; // ReLU kink / curvature inside the bracket
                    continue;
                }
                let analytic = gvec[idx];
                prop_assert!(
                    (fd1 - analytic).abs() < 2e-2 + 5e-2 * fd1.abs(),
                    "{name}[{idx}]: fd {fd1} vs analytic {analytic}"
                );
                checked += 1;
            }
        }
        prop_assert!(
            checked * 10 >= (checked + skipped) * 7,
            "too many FD-unstable entries: {checked} checked, {skipped} skipped"
        );
        Ok(())
    });
}

/// Transformer training state round-trips through the checkpoint
/// container bit-exactly — slots, dense extras and momentum buffers all
/// restore into a differently-seeded state, for every method family.
#[test]
fn prop_transformer_checkpoint_roundtrip() {
    use blocksparse::tensor::HostValue;
    prop_check("transformer checkpoint roundtrip", 5, |g| {
        let method = *g.pick(&["kpd", "group_lasso", "elastic_gl", "rigl_block", "dense"]);
        let (vocab, seq, nb) = (10usize, 4usize, 4usize);
        let cfg = SpecConfig::transformer(
            "ck_tf", "lm_tiny", method, vocab, seq, 8, 2, 12, 2, 2, 2, 2, nb,
        );
        let be = NativeBackend::from_spec(cfg).map_err(|e| e.to_string())?;
        let spec = be.spec("ck_tf").map_err(|e| e.to_string())?.clone();
        let mut state = be.init_state("ck_tf", g.case as u32).map_err(|e| e.to_string())?;
        // a couple of real steps so momentum buffers are non-trivial
        let toks: Vec<i32> = (0..nb * seq).map(|_| g.usize_in(0, vocab - 1) as i32).collect();
        let y: Vec<i32> = (0..nb * seq).map(|_| g.usize_in(0, vocab - 1) as i32).collect();
        let bx = HostValue::I32 { shape: vec![nb, seq], data: toks };
        let by = HostValue::I32 { shape: vec![nb, seq], data: y };
        let hyper: Vec<f32> = spec
            .hyper
            .iter()
            .map(|h| if h == "lr" { 0.05 } else { 0.01 })
            .collect();
        for _ in 0..2 {
            be.train_step(&mut state, &bx, &by, &hyper).map_err(|e| e.to_string())?;
        }

        let dir = std::env::temp_dir().join("bs_prop_tf_ckpt");
        let path = dir.join(format!("c{}.bsck", g.case));
        Checkpoint::from_state(&state).save(&path).map_err(|e| e.to_string())?;
        let back = Checkpoint::load(&path).map_err(|e| e.to_string())?;
        let mut other = be.init_state("ck_tf", g.case as u32 + 999).map_err(|e| e.to_string())?;
        back.restore_state(&mut other).map_err(|e| e.to_string())?;
        for (n, t) in state.param_names.iter().zip(&state.params) {
            let o = other.param(n).map_err(|e| e.to_string())?;
            prop_assert!(t.data() == o.data(), "param '{n}' did not round-trip ({method})");
        }
        for ((n, t), o) in state.opt_names.iter().zip(&state.opt).zip(&other.opt) {
            prop_assert!(t.data() == o.data(), "opt slot '{n}' did not round-trip ({method})");
        }
        Ok(())
    });
}

#[test]
fn prop_block_fro_invariant_under_block_permutation() {
    // permuting whole blocks permutes the norm grid (sum preserved)
    prop_check("block fro permutation", 40, |g| {
        let (m1, n1, m2, n2) = (g.usize_in(1, 3), g.usize_in(1, 3),
                                g.usize_in(1, 3), g.usize_in(1, 3));
        let w = Tensor::new(&[m1 * m2, n1 * n2],
                            g.normal_vec(m1 * m2 * n1 * n2)).unwrap();
        let norms = w.block_fro_norms(m2, n2).unwrap();
        let total: f32 = norms.data().iter().map(|v| v * v).sum();
        let frob: f32 = w.data().iter().map(|v| v * v).sum();
        prop_assert!(close(total, frob, 1e-3, 1e-3), "{total} vs {frob}");
        Ok(())
    });
}
