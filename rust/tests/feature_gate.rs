//! Compile guard for the feature split: with default features the `xla`
//! crate must be absent from the dependency graph and `blocksparse::runtime`
//! must not exist. This whole file is compiled only without `pjrt`, so it
//! doubles as a regression test that the default build stays native-only.
#![cfg(not(feature = "pjrt"))]

use blocksparse::backend::{self, Backend};

#[test]
fn default_features_exclude_pjrt() {
    // cfg-level guard: this test file vanishes when the feature is on, so
    // reaching this assertion means the default set really excludes it.
    assert!(!cfg!(feature = "pjrt"));
}

#[test]
fn default_backend_is_native() {
    let be = backend::open_default().unwrap();
    assert_eq!(be.name(), "native-cpu");
    assert!(be.specs().len() >= 10, "default registry too small");
    assert!(be.spec("t1_kpd_b2x2").is_ok());
}

#[test]
fn forcing_pjrt_fails_with_guidance() {
    let err = backend::open(std::path::Path::new("artifacts"), Some("pjrt"))
        .err()
        .expect("pjrt must be unavailable without the feature");
    let msg = format!("{err:#}");
    assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
}
