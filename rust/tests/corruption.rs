//! Hostile-input coverage for the BSRM container loaders (ISSUE-10
//! satellite 1): deterministic byte-flip and truncation sweeps over BOTH
//! container versions (v1 legacy frame, v2 aligned layout) and BOTH
//! payload dtypes (f32, int8), through the read path and the mmap path.
//!
//! The contract under test:
//!
//! * the **read path** (`BsrModel::load`, `QuantModel::load`,
//!   `load_auto`) CRC-checks every byte it returns, so *every* single-byte
//!   flip and *every* truncation must surface as a typed error — never a
//!   panic, never a silently-wrong model;
//! * the **mmap path** skips only the payload-wide CRC sweep. Flips in
//!   anything it interprets (prologue, header, padding) must still be
//!   typed errors; flips in the stored payload CRC are invisible to it
//!   (same logits as the clean file); flips inside the payload may load —
//!   but then the model must validate and forward without panicking,
//!   because the index arrays are copied + re-validated and only block
//!   *values* stay mapped;
//! * header fields are untrusted until their CRC passes, and even a
//!   forged-CRC header cannot drive allocation: derived array extents are
//!   bounds-checked against the payload before anything is allocated.

use blocksparse::checkpoint::crc32;
use blocksparse::infer::bsr::model_forward;
use blocksparse::infer::mmap::{open_bsr_mmap, open_model_mmap, open_quant_mmap};
use blocksparse::infer::quant::{model_forward_q8, quantize_model, QuantModel};
use blocksparse::infer::{load_auto, BsrLayer, BsrModel};
use std::path::{Path, PathBuf};

const PROLOGUE_LEN: usize = 40;

fn dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("bs_corruption_test").join(name);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic xorshift64* — the sweep must replay bit-identically.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn f32(&mut self) -> f32 {
        (self.next() % 2000) as f32 / 1000.0 - 1.0
    }
}

/// Dense (m×n) weights with exact-zero 2×2 blocks carved out, so the
/// packed fixture has real holes (occupied and empty block-rows both).
fn dense_with_holes(m: usize, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng(seed | 1);
    let mut w: Vec<f32> = (0..m * n).map(|_| rng.f32()).collect();
    for i1 in 0..m / 2 {
        for j1 in 0..n / 2 {
            if (i1 + j1) % 3 == 0 {
                for i2 in 0..2 {
                    for j2 in 0..2 {
                        w[(i1 * 2 + i2) * n + j1 * 2 + j2] = 0.0;
                    }
                }
            }
        }
    }
    w
}

/// The fixture: 12 → 8 → 6, 2×2 blocks, spec/method strings sized so the
/// v2 header end is NOT 8-aligned (the padding region must exist for the
/// sweep to exercise the pad check).
fn fixture() -> BsrModel {
    let w1 = dense_with_holes(8, 12, 0xC0FF);
    let w2 = dense_with_holes(6, 8, 0xBEEF);
    BsrModel {
        spec: "czoo".into(),
        method: "kpd".into(),
        in_dim: 12,
        out_dim: 6,
        layers: vec![
            BsrLayer::from_dense("fc1", &w1, 8, 12, 2, 2).unwrap(),
            BsrLayer::from_dense("fc2", &w2, 6, 8, 2, 2).unwrap(),
        ],
    }
}

fn probe_input(in_dim: usize) -> Vec<f32> {
    let mut rng = Rng(0x51EE7);
    (0..in_dim).map(|_| rng.f32()).collect()
}

fn u32_at(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap())
}

fn u64_at(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap())
}

/// Write `bytes` with the byte at `pos` xor-flipped.
fn write_flipped(path: &Path, bytes: &[u8], pos: usize, mask: u8) {
    let mut b = bytes.to_vec();
    b[pos] ^= mask;
    std::fs::write(path, &b).unwrap();
}

/// Every error must format through the anyhow chain without panicking and
/// carry a non-empty root cause.
fn assert_typed(err: anyhow::Error, what: &str) {
    let msg = format!("{err:#}");
    assert!(!msg.trim().is_empty(), "{what}: empty error message");
}

// ------------------------------------------------------------- byte flips

#[test]
fn v2_read_path_rejects_every_single_byte_flip() {
    let model = fixture();
    let d = dir("v2_read");
    let clean = d.join("clean.bsm");
    model.save(&clean).unwrap();
    let bytes = std::fs::read(&clean).unwrap();
    let hurt = d.join("hurt.bsm");
    for pos in 0..bytes.len() {
        write_flipped(&hurt, &bytes, pos, 0xFF);
        let err = BsrModel::load(&hurt)
            .err()
            .unwrap_or_else(|| panic!("flip at byte {pos} loaded cleanly on the read path"));
        assert_typed(err, &format!("flip at {pos}"));
        // spot-check the dtype-routing front door on a strided subset
        if pos % 97 == 0 {
            assert!(load_auto(&hurt).is_err(), "load_auto accepted flip at {pos}");
        }
    }
}

#[test]
fn v1_read_path_rejects_every_single_byte_flip() {
    let model = fixture();
    let d = dir("v1_read");
    let clean = d.join("clean.bsm");
    model.save_v1(&clean).unwrap();
    assert_eq!(BsrModel::load(&clean).unwrap(), model);
    let bytes = std::fs::read(&clean).unwrap();
    let hurt = d.join("hurt.bsm");
    for pos in 0..bytes.len() {
        write_flipped(&hurt, &bytes, pos, 0xFF);
        let err = BsrModel::load(&hurt)
            .err()
            .unwrap_or_else(|| panic!("v1 flip at byte {pos} loaded cleanly"));
        assert_typed(err, &format!("v1 flip at {pos}"));
        // the mmap front door falls back to the read path for v1 — same
        // guarantee, checked on a strided subset to bound the sweep
        if pos % 61 == 0 {
            assert!(open_bsr_mmap(&hurt).is_err(), "mmap fallback accepted v1 flip at {pos}");
        }
    }
}

#[test]
fn int8_read_path_rejects_every_single_byte_flip() {
    let q = quantize_model(&fixture()).unwrap();
    let d = dir("int8_read");
    let clean = d.join("clean.bsm");
    q.save(&clean).unwrap();
    let bytes = std::fs::read(&clean).unwrap();
    let hurt = d.join("hurt.bsm");
    for pos in 0..bytes.len() {
        write_flipped(&hurt, &bytes, pos, 0xFF);
        let err = QuantModel::load(&hurt)
            .err()
            .unwrap_or_else(|| panic!("int8 flip at byte {pos} loaded cleanly"));
        assert_typed(err, &format!("int8 flip at {pos}"));
        if pos % 97 == 0 {
            assert!(load_auto(&hurt).is_err(), "load_auto accepted int8 flip at {pos}");
        }
    }
}

/// The mmap path skips only the payload CRC sweep. Partition the file:
/// bytes the open *interprets* (prologue minus the stored payload CRC,
/// header, padding) must fail typed; the stored payload CRC itself is
/// dead weight to this path (clean logits); payload bytes may load — and
/// must then forward without panicking. (Platform-gated like the fast
/// path itself: elsewhere `open_bsr_mmap` is the read path, whose flip
/// behaviour the read-path sweeps already pin.)
#[cfg(all(unix, target_endian = "little"))]
#[test]
fn v2_mmap_path_flags_everything_it_interprets() {
    let model = fixture();
    let d = dir("v2_mmap");
    let clean = d.join("clean.bsm");
    model.save(&clean).unwrap();
    let bytes = std::fs::read(&clean).unwrap();
    let payload_off = u64_at(&bytes, 16) as usize;
    assert!(payload_off > PROLOGUE_LEN, "fixture has no header?");
    let x = probe_input(model.in_dim);
    let clean_logits = {
        let (m, stats) = open_bsr_mmap(&clean).unwrap();
        assert!(stats.resident_bytes < stats.file_bytes, "fixture too small to map lazily");
        model_forward(&m, &x, 1).unwrap()
    };
    let hurt = d.join("hurt.bsm");
    let mut payload_accepts = 0usize;
    for pos in 0..bytes.len() {
        write_flipped(&hurt, &bytes, pos, 0xFF);
        let opened = open_bsr_mmap(&hurt);
        if (32..36).contains(&pos) {
            // stored payload CRC: invisible to the zero-copy open
            let (m, _) = opened.unwrap_or_else(|e| {
                panic!("payload-CRC flip at {pos} must map cleanly: {e:#}")
            });
            assert_eq!(model_forward(&m, &x, 1).unwrap(), clean_logits);
        } else if pos < payload_off {
            let err = opened
                .err()
                .unwrap_or_else(|| panic!("interpreted-byte flip at {pos} mapped cleanly"));
            assert_typed(err, &format!("mmap flip at {pos}"));
        } else {
            // payload byte: an index-array flip is usually caught by
            // validate; a block-value flip loads and must forward — wrong
            // logits are acceptable, UB/panic is not
            match opened {
                Ok((m, _)) => {
                    payload_accepts += 1;
                    let z = model_forward(&m, &x, 1).unwrap();
                    assert_eq!(z.len(), model.out_dim);
                }
                Err(e) => assert_typed(e, &format!("payload flip at {pos}")),
            }
        }
    }
    // block values dominate the payload, so most payload flips must have
    // exercised the accept-and-forward arm
    assert!(payload_accepts > 0, "no payload flip reached the forward kernel");
}

#[cfg(all(unix, target_endian = "little"))]
#[test]
fn int8_mmap_path_flags_everything_it_interprets() {
    let q = quantize_model(&fixture()).unwrap();
    let d = dir("int8_mmap");
    let clean = d.join("clean.bsm");
    q.save(&clean).unwrap();
    let bytes = std::fs::read(&clean).unwrap();
    let payload_off = u64_at(&bytes, 16) as usize;
    let x = probe_input(q.in_dim);
    let clean_logits = {
        let (m, _) = open_quant_mmap(&clean).unwrap();
        model_forward_q8(&m, &x, 1).unwrap()
    };
    let hurt = d.join("hurt.bsm");
    for pos in 0..bytes.len() {
        write_flipped(&hurt, &bytes, pos, 0xFF);
        let opened = open_quant_mmap(&hurt);
        if (32..36).contains(&pos) {
            let (m, _) = opened.unwrap_or_else(|e| {
                panic!("payload-CRC flip at {pos} must map cleanly: {e:#}")
            });
            assert_eq!(model_forward_q8(&m, &x, 1).unwrap(), clean_logits);
        } else if pos < payload_off {
            let err = opened
                .err()
                .unwrap_or_else(|| panic!("int8 interpreted-byte flip at {pos} mapped cleanly"));
            assert_typed(err, &format!("int8 mmap flip at {pos}"));
        } else {
            match opened {
                Ok((m, _)) => {
                    let z = model_forward_q8(&m, &x, 1).unwrap();
                    assert_eq!(z.len(), q.out_dim);
                }
                Err(e) => assert_typed(e, &format!("int8 payload flip at {pos}")),
            }
        }
    }
}

// ------------------------------------------------------------- truncation

/// Every truncation — pinned boundary lengths plus a seeded sample of the
/// interior — must fail typed on every loader front door, both versions,
/// both dtypes. A prefix of a valid artifact is never a valid artifact.
#[test]
fn truncation_always_fails_loudly_on_every_path() {
    let model = fixture();
    let q = quantize_model(&model).unwrap();
    let d = dir("trunc");
    let f32_path = d.join("f32.bsm");
    let v1_path = d.join("v1.bsm");
    let q_path = d.join("q8.bsm");
    model.save(&f32_path).unwrap();
    model.save_v1(&v1_path).unwrap();
    q.save(&q_path).unwrap();

    let cut = d.join("cut.bsm");
    let check = |src: &Path, label: &str| {
        let bytes = std::fs::read(src).unwrap();
        let mut lens: Vec<usize> = vec![
            0, 1, 3, 4, 7, 8, 11, 12, 16, 24, 32, 36, 39, PROLOGUE_LEN,
            bytes.len() / 2,
            bytes.len() - 8,
            bytes.len() - 1,
        ];
        let mut rng = Rng(0xDEAD_0010);
        lens.extend((0..24).map(|_| (rng.next() as usize) % bytes.len()));
        lens.retain(|&l| l < bytes.len());
        for len in lens {
            std::fs::write(&cut, &bytes[..len]).unwrap();
            assert!(BsrModel::load(&cut).is_err(), "{label}: read path took {len}-byte prefix");
            assert!(QuantModel::load(&cut).is_err(), "{label}: quant read took {len} bytes");
            assert!(load_auto(&cut).is_err(), "{label}: load_auto took {len} bytes");
            assert!(open_bsr_mmap(&cut).is_err(), "{label}: mmap took {len} bytes");
            assert!(open_model_mmap(&cut).is_err(), "{label}: model mmap took {len} bytes");
        }
    };
    check(&f32_path, "v2/f32");
    check(&v1_path, "v1");
    check(&q_path, "v2/int8");

    // degenerate non-artifacts get the same typed refusal
    std::fs::write(&cut, b"").unwrap();
    assert!(load_auto(&cut).is_err());
    std::fs::write(&cut, b"BSRMjunk").unwrap();
    assert!(load_auto(&cut).is_err());
    std::fs::write(&cut, b"totally not a model file").unwrap();
    assert!(load_auto(&cut).is_err());
}

// ----------------------------------------------------- root-cause triage

/// The folded CRC triage test (formerly three positions in the unit
/// suite): representative corruption sites must name their root cause, so
/// an operator staring at a failed deploy knows *which* guard fired.
#[test]
fn corrupt_fields_report_their_root_cause() {
    let model = fixture();
    let d = dir("triage");
    let clean = d.join("clean.bsm");
    model.save(&clean).unwrap();
    let bytes = std::fs::read(&clean).unwrap();
    let header_len = u32_at(&bytes, 8) as usize;
    let header_end = PROLOGUE_LEN + header_len;
    let payload_off = u64_at(&bytes, 16) as usize;
    assert!(payload_off > header_end, "fixture must leave alignment padding to corrupt");

    let hurt = d.join("hurt.bsm");
    let expect = |pos: usize, mask: u8, needle: &str| {
        write_flipped(&hurt, &bytes, pos, mask);
        let msg = format!("{:#}", BsrModel::load(&hurt).unwrap_err());
        assert!(msg.contains(needle), "flip at {pos}: got {msg:?}, wanted {needle:?}");
    };
    expect(0, 0xFF, "not a BSRM");
    expect(4, 0xFF, "unsupported BSR model version");
    expect(12, 0xFF, "header CRC mismatch"); // stored header CRC
    expect(PROLOGUE_LEN + 2, 0xFF, "header CRC mismatch"); // header body
    expect(header_end, 0x55, "padding corrupt");
    expect(32, 0xFF, "payload CRC mismatch"); // stored payload CRC
    expect(payload_off + 1, 0xFF, "payload CRC mismatch"); // payload body
    expect(36, 0xFF, "dtype"); // dtype code out of range

    // v1's single whole-body CRC names its own root cause
    let v1 = d.join("v1.bsm");
    model.save_v1(&v1).unwrap();
    let v1_bytes = std::fs::read(&v1).unwrap();
    write_flipped(&hurt, &v1_bytes, v1_bytes.len() / 2, 0xFF);
    let msg = format!("{:#}", BsrModel::load(&hurt).unwrap_err());
    assert!(msg.contains("CRC mismatch"), "{msg:?}");
}

// --------------------------------------------- forged-header allocation

/// A header with a *valid* CRC but hostile derived counts must still die
/// typed — bounds checks run before any allocation, so a forged nnz of
/// u32::MAX (≈68 GB of implied block values) returns instantly instead of
/// OOM-ing the server. This pins the "never over-allocation" half of the
/// loader contract that the CRC sweeps cannot reach.
#[test]
fn forged_header_fields_cannot_drive_allocation() {
    let model = fixture();
    let d = dir("forged");
    let clean = d.join("clean.bsm");
    model.save(&clean).unwrap();
    let bytes = std::fs::read(&clean).unwrap();
    let header_len = u32_at(&bytes, 8) as usize;
    let header = &bytes[PROLOGUE_LEN..PROLOGUE_LEN + header_len];

    // walk the wire header to layer 0's nnz field:
    // spec str | method str | in_dim | out_dim | num_layers |
    //   name str | m | n | m2 | n2 | nnz | ...
    let mut off = 0usize;
    let skip_str = |o: &mut usize| {
        let len = u32_at(header, *o) as usize;
        *o += 4 + len;
    };
    skip_str(&mut off); // spec
    skip_str(&mut off); // method
    off += 8; // in_dim, out_dim
    let num_layers_at = PROLOGUE_LEN + off;
    off += 4; // num_layers
    skip_str(&mut off); // layer 0 name
    off += 16; // m, n, m2, n2
    let nnz_at = PROLOGUE_LEN + off;

    let forge = |field_at: usize, value: u32| {
        let mut b = bytes.clone();
        b[field_at..field_at + 4].copy_from_slice(&value.to_le_bytes());
        let h = crc32(&b[PROLOGUE_LEN..PROLOGUE_LEN + header_len]);
        b[12..16].copy_from_slice(&h.to_le_bytes());
        b
    };

    let hurt = d.join("hurt.bsm");

    // sanity: re-signing the untouched header still loads — the forge
    // helper itself is not what trips the guards below
    std::fs::write(&hurt, forge(nnz_at, u32_at(&bytes, nnz_at))).unwrap();
    assert_eq!(BsrModel::load(&hurt).unwrap(), model);

    // nnz = u32::MAX: the derived col_idx/blocks extents blow past the
    // payload bounds check on both paths, long before any Vec grows
    std::fs::write(&hurt, forge(nnz_at, u32::MAX)).unwrap();
    let msg = format!("{:#}", BsrModel::load(&hurt).unwrap_err());
    assert!(msg.contains("fc1"), "read path must name the offending array: {msg:?}");
    let msg = format!("{:#}", open_bsr_mmap(&hurt).unwrap_err());
    assert!(msg.contains("fc1"), "mmap path must name the offending array: {msg:?}");

    // num_layers = u32::MAX: the record loop parses until the header runs
    // out — typed error, no with_capacity(4B) reservation
    std::fs::write(&hurt, forge(num_layers_at, u32::MAX)).unwrap();
    assert!(BsrModel::load(&hurt).is_err());
    assert!(open_bsr_mmap(&hurt).is_err());
}
