//! SIMD dispatch contract tests — in their own binary (own process) so
//! the `force`/`unforce` pin test cannot race the lib unit tests, which
//! bit-compare kernels resolved through `simd::active()`.
//!
//! Every other test here uses only the explicit-kind `*_with` APIs, so the
//! pin test is the sole reader/writer of the process-wide pin. Parity is
//! checked scalar-vs-`detect()`: on a scalar-only host both sides run the
//! same loops and the assertions degenerate to exact equality.

use blocksparse::backend::native::linalg;
use blocksparse::backend::native::simd::{self, SimdKind};
use blocksparse::backend::native::kpd;
use blocksparse::flops::KpdDims;
use blocksparse::infer::{bsr, synth_block_sparse_weights, BsrLayer};
use blocksparse::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Relative-ish closeness for f32 re-association drift across SIMD lanes.
fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

fn assert_close_all(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(close(*g, *w, tol), "{what}[{i}]: {g} vs {w}");
    }
}

/// Scalar and detected-SIMD kinds agree (under f32 re-association
/// tolerance) on every matmul variant, across ragged shapes that exercise
/// both the vector bodies and every tail width.
#[test]
fn matmul_variants_scalar_vs_simd_parity() {
    let vec_kind = simd::detect();
    let mut rng = Rng::new(0x51D);
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 7, 5), (7, 130, 9), (16, 257, 33)] {
        let a = rand_vec(&mut rng, m * k);
        let b_nn = rand_vec(&mut rng, k * n);
        let b_nt = rand_vec(&mut rng, n * k);
        assert_close_all(
            &linalg::matmul_nn_with(vec_kind, &a, &b_nn, m, k, n),
            &linalg::matmul_nn_with(SimdKind::Scalar, &a, &b_nn, m, k, n),
            1e-4,
            "matmul_nn",
        );
        assert_close_all(
            &linalg::matmul_nt_with(vec_kind, &a, &b_nt, m, k, n),
            &linalg::matmul_nt_with(SimdKind::Scalar, &a, &b_nt, m, k, n),
            1e-4,
            "matmul_nt",
        );
        let a_tn = rand_vec(&mut rng, k * m);
        assert_close_all(
            &linalg::matmul_tn_with(vec_kind, &a_tn, &b_nn, k, m, n),
            &linalg::matmul_tn_with(SimdKind::Scalar, &a_tn, &b_nn, k, m, n),
            1e-4,
            "matmul_tn",
        );
    }
}

/// Same parity contract for the masked block-sparse matmul and the packed
/// BSR forward, at several occupancy levels.
#[test]
fn block_sparse_and_bsr_scalar_vs_simd_parity() {
    let vec_kind = simd::detect();
    let mut rng = Rng::new(0xB5);
    let (nb, m, n, m2, n2) = (8usize, 24usize, 64usize, 8usize, 16usize);
    let x = rand_vec(&mut rng, nb * n);
    for occupancy in [1.0f64, 0.5, 0.25] {
        let (w, mask) = synth_block_sparse_weights(&mut rng, m, n, m2, n2, occupancy);
        let scalar_z =
            linalg::block_sparse_matmul_nt_with(SimdKind::Scalar, &x, &w, &mask, nb, m, n, m2, n2)
                .expect("scalar block-sparse");
        let simd_z =
            linalg::block_sparse_matmul_nt_with(vec_kind, &x, &w, &mask, nb, m, n, m2, n2)
                .expect("simd block-sparse");
        assert_close_all(&simd_z, &scalar_z, 1e-4, "block_sparse");

        let layer = BsrLayer::from_dense("fc", &w, m, n, m2, n2).expect("layer");
        for relu in [false, true] {
            let scalar_b = bsr::bsr_forward_with(SimdKind::Scalar, &x, nb, &layer, relu)
                .expect("scalar bsr");
            let simd_b =
                bsr::bsr_forward_with(vec_kind, &x, nb, &layer, relu).expect("simd bsr");
            assert_close_all(&simd_b, &scalar_b, 1e-4, "bsr");
        }
    }
}

/// KPD forward parity between the pinned-scalar and detected kinds.
#[test]
fn kpd_forward_scalar_vs_simd_parity() {
    let vec_kind = simd::detect();
    let mut rng = Rng::new(0x4B);
    let d = KpdDims { m1: 3, n1: 4, m2: 4, n2: 5, r: 3 };
    let nb = 6usize;
    let x = rand_vec(&mut rng, nb * d.n1 * d.n2);
    let s = rand_vec(&mut rng, d.m1 * d.n1);
    let a = rand_vec(&mut rng, d.r * d.m1 * d.n1);
    let b = rand_vec(&mut rng, d.r * d.m2 * d.n2);
    let (z_s, _) = kpd::forward_with(SimdKind::Scalar, &x, nb, &s, &a, &b, d);
    let (z_v, _) = kpd::forward_with(vec_kind, &x, nb, &s, &a, &b, d);
    assert_close_all(&z_v, &z_s, 1e-4, "kpd forward");
}

/// Central-finite-difference gradient check of the KPD backward pass
/// *under the detected SIMD kind*: the analytic dS/dA/dB of the smooth
/// quadratic loss L = ½‖Z‖² must match central differences of the same
/// SIMD forward. This is the FD coverage the golden (scalar-pinned) tests
/// cannot give the vector bodies.
#[test]
fn kpd_fd_gradients_under_simd_kind() {
    let kind = simd::detect();
    let mut rng = Rng::new(0xFD);
    let d = KpdDims { m1: 2, n1: 3, m2: 2, n2: 3, r: 2 };
    let nb = 4usize;
    let x = rand_vec(&mut rng, nb * d.n1 * d.n2);
    let s = rand_vec(&mut rng, d.m1 * d.n1);
    let a = rand_vec(&mut rng, d.r * d.m1 * d.n1);
    let b = rand_vec(&mut rng, d.r * d.m2 * d.n2);

    let loss = |s: &[f32], a: &[f32], b: &[f32]| -> f64 {
        let (z, _) = kpd::forward_with(kind, x.as_slice(), nb, s, a, b, d);
        0.5 * z.iter().map(|v| *v as f64 * *v as f64).sum::<f64>()
    };
    // analytic grads: dL/dZ = Z for the quadratic loss
    let (z, tprime) = kpd::forward_with(kind, &x, nb, &s, &a, &b, d);
    let grads = kpd::backward_with(kind, &x, nb, &s, &a, z.as_slice(), &tprime, d);

    let h = 1e-2f32;
    let check = |name: &str, base: &[f32], analytic: &[f32], which: usize| {
        for idx in 0..base.len() {
            let mut plus = base.to_vec();
            plus[idx] += h;
            let mut minus = base.to_vec();
            minus[idx] -= h;
            let (lp, lm) = match which {
                0 => (loss(&plus, &a, &b), loss(&minus, &a, &b)),
                1 => (loss(&s, &plus, &b), loss(&s, &minus, &b)),
                _ => (loss(&s, &a, &plus), loss(&s, &a, &minus)),
            };
            let fd = ((lp - lm) / (2.0 * h as f64)) as f32;
            let an = analytic[idx];
            assert!(
                (fd - an).abs() < 1e-2 + 3e-2 * fd.abs().max(an.abs()),
                "{name}[{idx}] under {kind:?}: fd {fd} vs analytic {an}"
            );
        }
    };
    check("dS", &s, &grads.gs, 0);
    check("dA", &a, &grads.ga, 1);
    check("dB", &b, &grads.gb, 2);
}

/// `force` pins `active()` process-wide until `unforce`; forcing a kind
/// the CPU cannot run downgrades to scalar rather than crashing later.
#[test]
fn force_pin_overrides_dispatch_until_unforce() {
    let detected = simd::detect();
    simd::force(SimdKind::Scalar);
    assert_eq!(simd::active(), SimdKind::Scalar);
    simd::force(detected);
    assert_eq!(simd::active(), detected);
    // an unavailable ISA request downgrades to scalar at force time
    let foreign = match detected {
        SimdKind::Avx2 => SimdKind::Neon,
        _ => SimdKind::Avx2,
    };
    simd::force(foreign);
    assert_eq!(simd::active(), SimdKind::Scalar);
    simd::unforce();
    assert_eq!(simd::active(), simd::dispatched());
}

/// The `BS_NATIVE_SIMD` env knob governs `dispatched()`: CI runs this
/// binary once unset and once with `BS_NATIVE_SIMD=0`, so both arms of
/// the match are exercised across the two runs.
#[test]
fn env_knob_governs_dispatch() {
    let d = simd::dispatched();
    match std::env::var("BS_NATIVE_SIMD").ok().as_deref() {
        Some("0") | Some("off") | Some("scalar") => assert_eq!(d, SimdKind::Scalar),
        Some("avx2") => assert!(d == SimdKind::Avx2 || d == SimdKind::Scalar),
        Some("neon") => assert!(d == SimdKind::Neon || d == SimdKind::Scalar),
        _ => assert_eq!(d, simd::detect()),
    }
}
