//! Quickstart: train a KPD-factorized linear classifier end to end.
//!
//! LEGACY REFERENCE: predates the `Backend` trait (PR 1) and still
//! drives `runtime::Runtime` directly, which requires `--features pjrt`
//! and real AOT artifacts; it is not a registered cargo example target,
//! so there is no `cargo run --example quickstart`. For a runnable
//! equivalent use `cargo run --release -- train --spec qs_kpd`
//! (see rust/README.md).
//!
//! Walks the whole public API: open the runtime over the AOT artifacts,
//! build a dataset, train with the paper's Eq. 4 objective, measure the
//! block sparsity of the materialized W, and compare the training cost
//! against the dense parameterization (Prop. 2).

use blocksparse::config::{Config, TrainConfig};
use blocksparse::coordinator::{self, experiment, probe, Trainer};
use blocksparse::flops;
use blocksparse::runtime::Runtime;
use blocksparse::util::human_count;

fn main() -> anyhow::Result<()> {
    // 1. open the runtime over artifacts/ (compiled once, cached)
    let rt = Runtime::new(blocksparse::artifact_dir())?;
    let spec_key = "t1_kpd_b2x2";
    let spec = rt.spec(spec_key)?.clone();
    println!("spec {spec_key}: {} on {} (batch {})", spec.method, spec.model, spec.batch);

    // 2. config + data (synthetic MNIST-like; drop IDX files in data/ to
    //    use the real thing)
    let mut cfg = TrainConfig::from_config(&Config::default(), spec_key);
    cfg.steps = 600;
    cfg.seeds = vec![0];
    cfg.lambda = 0.008;
    cfg.eval_every = 150;
    let (train, test) = coordinator::dataset_for(&spec, cfg.data_seed, 8192, 2048)?;
    println!("dataset: {} train / {} test examples", train.n, test.n);

    // 3. train
    let trainer = Trainer::new(&rt, &cfg);
    let outcome = trainer.run(0, &train, &test)?;
    println!("\nfinal test accuracy: {:.2}%  (loss {:.4})",
             outcome.test_acc, outcome.test_loss);

    // 4. inspect the learned block-wise sparse matrix
    let sparsity = probe::measure_sparsity(&rt, &spec, &outcome.state)?;
    let ws = rt.materialize(&outcome.state)?;
    for (name, w) in &ws {
        println!("slot {name}: W is {}x{}, block sparsity {:.1}%",
                 w.shape()[0], w.shape()[1], sparsity);
    }

    // 5. cost accounting: the paper's headline (Prop. 2)
    let (params, step_flops) = experiment::accounting(&spec);
    let dense_flops = flops::dense_step_flops(spec.batch as u64, 10, 784);
    println!("\ntraining params: {} (dense: 7.84K)", human_count(params as f64));
    println!("training FLOPs/step: {} (dense: {})",
             human_count(step_flops as f64), human_count(dense_flops as f64));
    println!("\nloss curve (every 100 steps):");
    for (step, v) in outcome.history.series("loss").iter().step_by(100) {
        println!("  step {step:>4}: {v:.4}");
    }
    Ok(())
}
