//! End-to-end driver: train a KPD-factorized decoder-only transformer LM
//! on a synthetic Markov byte corpus and log the loss curve — proving all
//! three layers compose on a real training workload (EXPERIMENTS.md §E2E).
//!
//! LEGACY REFERENCE: predates the `Backend` trait (PR 1) and still
//! drives `runtime::Runtime` directly, which requires `--features pjrt`
//! and real AOT artifacts; it is not a registered cargo example target,
//! so there is no `cargo run --example e2e_transformer`. For a runnable
//! equivalent use the `table3_transformers` bench.
//!
//! The model (lm_e2e: dim 192, depth 4, seq 128, ~5.6M dense-equivalent
//! params) trains through the full stack: rust data pipeline → PJRT
//! train_step (Pallas KPD forward + hand-derived backward inside) → Adam →
//! sparsity probe. `--dense` trains the uncompressed twin for the
//! params/FLOPs comparison.

use blocksparse::config::{Config, TrainConfig};
use blocksparse::coordinator::{self, experiment, probe, Trainer};
use blocksparse::metrics::History;
use blocksparse::runtime::Runtime;
use blocksparse::util::human_count;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps = args.iter().position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1)).and_then(|v| v.parse().ok()).unwrap_or(300);
    let spec_key = if args.iter().any(|a| a == "--dense") {
        "e2e_lm_dense"
    } else {
        "e2e_lm_kpd"
    };

    let rt = Runtime::new(blocksparse::artifact_dir())?;
    let spec = rt.spec(spec_key)?.clone();
    let (params, step_flops) = experiment::accounting(&spec);
    println!("E2E transformer LM: spec {spec_key}");
    println!("  model {} — vocab {} seq {} batch {}", spec.model,
             spec.num_classes, spec.input_shape[0], spec.batch);
    println!("  trainable params {} | slot FLOPs/step {}",
             human_count(params as f64), human_count(step_flops as f64));

    let mut cfg = TrainConfig::from_config(&Config::default(), spec_key);
    cfg.steps = steps;
    cfg.seeds = vec![0];
    cfg.lr = 1e-2;
    cfg.lambda = 1e-5; // light ℓ1 on S: sparsify without hurting the LM
    cfg.eval_every = (steps / 5).max(1);
    cfg.train_examples = 2048; // sequences
    cfg.test_examples = 256;
    let (train, test) = coordinator::dataset_for(&spec, cfg.data_seed,
                                                 cfg.train_examples, cfg.test_examples)?;
    println!("  corpus: {} train / {} test sequences\n", train.n, test.n);

    let trainer = Trainer::new(&rt, &cfg);
    let t0 = std::time::Instant::now();
    let outcome = trainer.run(0, &train, &test)?;
    let secs = t0.elapsed().as_secs_f64();

    print_loss_curve(&outcome.history, steps);
    let uniform = (spec.num_classes as f64).ln();
    println!("\nfinal: test CE {:.4} (uniform = ln({}) = {:.3}), per-token acc {:.2}%",
             outcome.test_loss, spec.num_classes, uniform, outcome.test_acc);
    assert!(outcome.test_loss.is_finite());
    println!("wall: {:.1}s ({:.0} ms/step, {:.0} tokens/s)",
             secs, 1e3 * secs / steps as f64,
             (steps * spec.batch * spec.input_shape[0]) as f64 / secs);
    if spec.method == "kpd" {
        let sp = probe::measure_sparsity(&rt, &spec, &outcome.state)?;
        println!("block sparsity of materialized weights: {sp:.1}%");
    }
    // loss-curve CSV for EXPERIMENTS.md
    let csv = format!("bench_results/e2e_{spec_key}.csv");
    std::fs::create_dir_all("bench_results")?;
    let mut out = String::from("step,loss\n");
    for (s, v) in outcome.history.series("loss") {
        out.push_str(&format!("{s},{v}\n"));
    }
    std::fs::write(&csv, out)?;
    println!("loss curve written to {csv}");
    Ok(())
}

fn print_loss_curve(h: &History, steps: usize) {
    println!("CE loss curve (regularizer excluded):");
    let series = if h.series("ce").is_empty() { h.series("loss") } else { h.series("ce") };
    let stride = (steps / 15).max(1);
    for (s, v) in series.iter().step_by(stride) {
        let bar = "#".repeat(((v / series[0].1) * 40.0) as usize);
        println!("  step {s:>5}: {v:>7.4} {bar}");
    }
}
