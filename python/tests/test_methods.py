"""L2 method semantics: every train step runs, optimizes, and respects its
method's invariants (mask freezing, regularizer monotonicity, RigL nnz
preservation, pruning targets, pattern penalty)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import methods as M
from compile import optim
from compile.models import MODELS, linear_model
from compile.kernels import ref

KEY = jax.random.PRNGKey(0)


def fake_batch(model, batch, seed=0):
    rng = np.random.default_rng(seed)
    if model.input_dtype == "i32":
        x = rng.integers(0, model.num_classes, (batch,) + model.input_shape,
                         dtype=np.int32)
        y = rng.integers(0, model.num_classes, (batch,) + model.input_shape,
                         dtype=np.int32)
    else:
        x = rng.standard_normal((batch,) + model.input_shape).astype(np.float32)
        y = rng.integers(0, model.num_classes, (batch,), dtype=np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def run_steps(bundle, model, hyper, steps=12, batch=16):
    params, opt = bundle.init(KEY)
    x, y = fake_batch(model, batch)
    first = None
    for _ in range(steps):
        params, opt, metrics = bundle.train_step(params, opt, x, y, *hyper)
        if first is None:
            first = float(metrics[0])
    return params, opt, float(metrics[0]), first


def test_kpd_loss_decreases():
    model = linear_model()
    b = M.kpd_method(model, M.uniform_blocks(model, (2, 4)), rank=2)
    _, _, last, first = run_steps(b, model, (0.001, 0.1), steps=25)
    assert last < first, (first, last)


def test_dense_loss_decreases():
    model = linear_model()
    b = M.dense_method(model)
    _, _, last, first = run_steps(b, model, (0.1,), steps=25)
    assert last < first


def test_group_lasso_reg_positive_and_shrinks_blocks():
    model = linear_model()
    b = M.group_lasso_method(model, M.uniform_blocks(model, (2, 4)))
    params, opt = b.init(KEY)
    x, y = fake_batch(model, 16)
    norm0 = float(jnp.abs(params["fc.W"]).sum())
    for _ in range(30):
        params, opt, m = b.train_step(params, opt, x, y,
                                      jnp.float32(0.05), jnp.float32(0.0),
                                      jnp.float32(0.1))
    assert float(m[3]) > 0.0  # reg metric
    assert float(jnp.abs(params["fc.W"]).sum()) < norm0


def test_rigl_mask_frozen_during_steps():
    model = linear_model()
    b = M.rigl_method(model, M.uniform_blocks(model, (2, 4)), density=0.5)
    params, opt = b.init(KEY)
    mask0 = np.asarray(params["fc.mask"]).copy()
    x, y = fake_batch(model, 16)
    for _ in range(5):
        params, opt, m = b.train_step(params, opt, x, y, jnp.float32(0.1))
    np.testing.assert_array_equal(np.asarray(params["fc.mask"]), mask0)
    # masked blocks receive no weight update
    w = np.asarray(params["fc.W"]).reshape(5, 2, 196, 4)
    dead = w * (1 - mask0[:, None, :, None])
    p0, _ = b.init(KEY)
    w0 = np.asarray(p0["fc.W"]).reshape(5, 2, 196, 4)
    dead0 = w0 * (1 - mask0[:, None, :, None])
    np.testing.assert_allclose(dead, dead0, rtol=1e-6, atol=1e-6)


def test_rigl_update_preserves_nnz_and_zeroes_grown():
    model = linear_model()
    b = M.rigl_method(model, M.uniform_blocks(model, (2, 4)), density=0.5)
    params, _ = b.init(KEY)
    nb = 5 * 196
    gnorm = jnp.asarray(np.random.default_rng(3).random(nb).astype(np.float32))
    new = b.extras["rigl_update"](params, gnorm, jnp.float32(0.3))
    m0 = np.asarray(params["fc.mask"])
    m1 = np.asarray(new["fc.mask"])
    assert abs(m1.sum() - m0.sum()) <= 1  # nnz preserved (ties ±1)
    grown = (m1 > 0) & (m0 == 0)
    w1 = np.asarray(new["fc.W"]).reshape(5, 2, 196, 4)
    assert np.abs(w1[grown.nonzero()[0], :, grown.nonzero()[1], :]).max() == 0.0


def test_prune_hits_global_target():
    model = linear_model()
    b = M.iter_prune_method(model)
    params, _ = b.init(KEY)
    new = b.extras["prune"](params, jnp.float32(0.7))
    mask = np.asarray(new["fc.emask"])
    sparsity = 1.0 - mask.mean()
    assert abs(sparsity - 0.7) < 0.02, sparsity
    # pruned entries are exactly the smallest-|w| ones
    w = np.abs(np.asarray(params["fc.W"])).ravel()
    thr = np.sort(w)[int(0.7 * w.size) - 1]
    assert np.abs(np.asarray(new["fc.W"])).ravel()[w <= thr].max() == 0.0


def test_pattern_penalty_drives_losers_to_zero():
    model = linear_model()
    pats = [M.uniform_blocks(model, (2, 2)), M.uniform_blocks(model, (2, 8))]
    b = M.pattern_method(model, pats, rank=2)
    params, opt = b.init(KEY)
    x, y = fake_batch(model, 32)
    # huge lambda1: everything should shrink towards zero fast
    for _ in range(40):
        params, opt, m = b.train_step(params, opt, x, y,
                                      jnp.float32(0.5), jnp.float32(0.01),
                                      jnp.float32(0.1))
    k = b.info["num_patterns"]
    snorms = [float(m[3 + k + p]) for p in range(k)]
    p0, _ = b.init(KEY)
    s0 = [float(jnp.abs(p0[f"p{i}.fc.S"]).sum()) for i in range(k)]
    assert all(sn < s * 0.8 for sn, s in zip(snorms, s0)), (snorms, s0)


def test_pattern_metrics_layout():
    model = linear_model()
    pats = [M.uniform_blocks(model, (2, 2)), M.uniform_blocks(model, (2, 4)),
            M.uniform_blocks(model, (2, 8))]
    b = M.pattern_method(model, pats, rank=1)
    assert b.metric_names[:3] == ("loss", "ce", "reg")
    assert b.metric_names[3:6] == ("acc_count_p0", "acc_count_p1", "acc_count_p2")
    assert b.metric_names[6:] == ("s_l1_p0", "s_l1_p1", "s_l1_p2")


def test_eval_step_counts_correct():
    model = linear_model()
    b = M.dense_method(model)
    params, _ = b.init(KEY)
    x, y = fake_batch(model, 64)
    m = b.eval_step(params, x, y)
    assert m.shape == (2,)
    assert 0 <= float(m[1]) <= 64


@pytest.mark.parametrize("name", ["lenet5", "vit_micro", "lm_micro"])
def test_kpd_on_all_models_runs(name):
    model = MODELS[name]()
    b = M.kpd_method(model, M.uniform_blocks(model, (4, 4) if name != "lenet5"
                                             else (2, 4)), rank=2,
                     optimizer="adam" if name == "lm_micro" else "sgd")
    params, opt = b.init(KEY)
    x, y = fake_batch(model, 4)
    params, opt, m = b.train_step(params, opt, x, y, jnp.float32(1e-3),
                                  jnp.float32(0.01))
    assert np.isfinite(float(m[0]))
    ev = b.eval_step(params, x, y)
    assert np.isfinite(float(ev[0]))


def test_optimizer_frozen_leaves():
    assert optim.is_frozen("fc.mask")
    assert not optim.is_frozen("fc.W")
    params = {"a.W": jnp.ones((2, 2)), "a.mask": jnp.ones((1, 1))}
    state = optim.sgd_init(params)
    assert "mom.a.W" in state and "mom.a.mask" not in state
    grads = {"a.W": jnp.ones((2, 2)), "a.mask": jnp.zeros((1, 1))}
    new_p, _ = optim.sgd_update(params, grads, state, jnp.float32(0.1))
    np.testing.assert_array_equal(np.asarray(new_p["a.mask"]), np.ones((1, 1)))


def test_adam_bias_correction_first_step():
    params = {"w": jnp.ones((3,))}
    state = optim.adam_init(params)
    grads = {"w": jnp.full((3,), 0.5)}
    new_p, new_s = optim.adam_update(params, grads, state, jnp.float32(0.1))
    # first Adam step ≈ -lr * sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0 - 0.1, rtol=1e-3)
    assert float(new_s["t"]) == 1.0
