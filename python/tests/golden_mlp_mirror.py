"""Reference mirror of the native multi-layer KPD training loop.

Derives the pinned values of the Rust golden-run regression test
(`rust/tests/mlp.rs::golden_t2_mlp_fifty_steps`): a fixed-seed 50-step run
of the `t2_kpd_16x8_8x4_4x2` spec on deterministic class-structured data
(uniform class templates + uniform noise, labels `i % 10`). The mirror
replicates, bit-faithfully where floats allow:

* the Rust `util::rng::Rng` stream (SplitMix64 → Xoshiro256**) including
  the `seed ^ fnv(key)` init-seed derivation and the exact draw order of
  `layers::init_state_parts` (per layer: A then B normals, S at ones);
* the training math (factorized KPD forward with ReLU between slots,
  softmax-CE, per-slot backward, SGD+momentum on A/B, plain SGD + ℓ1
  soft-threshold prox on S);
* the sparsity probe (materialize W per slot, block Frobenius norms,
  relative threshold 0.02 — `sparsity::block_sparsity`).

Differences remaining vs the Rust run: f64 here vs f32 there, numpy BLAS
accumulation order vs the cache-blocked sequential kernels, and ≤1-ulp
libm (ln/cos) deviations in the Box–Muller normals. Running the mirror in
both f64 and f32 (`--dtype f32`) brackets that drift; the Rust test's
tolerances are set an order of magnitude above it.

Run: python3 python/tests/golden_mlp_mirror.py [--dtype f32]
"""

import argparse

import numpy as np

M64 = (1 << 64) - 1


class SplitMix64:
    def __init__(self, seed):
        self.state = seed & M64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return (z ^ (z >> 31)) & M64


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Rng:
    """Xoshiro256** seeded via SplitMix64 — mirrors rust/src/util/rng.rs."""

    def __init__(self, seed):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self):
        s = self.s
        result = (rotl((s[1] * 5) & M64, 7) * 9) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def uniform(self):
        # exact: 24-bit integer times 2^-24
        return (self.next_u64() >> 40) * (1.0 / (1 << 24))

    def normal(self):
        # Box–Muller, f32 in Rust; f64 here (bracketed by --dtype f32)
        while True:
            u1 = self.uniform()
            if u1 <= 1.1920929e-07:  # f32::EPSILON guard in the Rust source
                continue
            u2 = self.uniform()
            r = np.sqrt(-2.0 * np.log(u1))
            return r * np.cos(2.0 * np.pi * u2)


def fnv(name: str) -> int:
    h = 0xCBF29CE484222325
    for b in name.encode():
        h = ((h ^ b) * 0x100000001B3) & M64
    return h


# ---------------------------------------------------------------- spec

KEY = "t2_kpd_16x8_8x4_4x2"
WIDTHS = [784, 304, 100, 10]
BLOCKS = [(8, 16), (4, 8), (2, 4)]  # (m2, n2) per slot
RANK = 5
MU = 0.9

DATA_SEED = 123
N_DATA, NB, STEPS = 256, 64, 50
# calibrated so the 50-step run sits mid-collapse: enough prox pressure
# that block sparsity is in the teens-to-thirties per layer (a pin at 0%
# or 100% would be insensitive to backward-chain drift)
LAM, LR = 0.2, 0.1


def make_data(dt):
    """Class-structured data, exactly as the Rust golden test builds it:
    one Rng(DATA_SEED) stream draws 10 class templates (784 uniforms in
    [-1, 1) each), then per-example noise; x = 0.8·tmpl[y] + 0.5·noise,
    y = i % 10 (deterministic integers — no float compare in labels)."""
    rng = Rng(DATA_SEED)
    tmpl = np.array(
        [rng.uniform() * 2.0 - 1.0 for _ in range(10 * WIDTHS[0])], dtype=dt
    ).reshape(10, WIDTHS[0])
    noise = np.array(
        [rng.uniform() * 2.0 - 1.0 for _ in range(N_DATA * WIDTHS[0])], dtype=dt
    ).reshape(N_DATA, WIDTHS[0])
    y = np.arange(N_DATA) % 10
    x = dt(0.8) * tmpl[y] + dt(0.5) * noise
    return x.astype(dt), y


def layer_dims():
    out = []
    for i, (m2, n2) in enumerate(BLOCKS):
        m, n = WIDTHS[i + 1], WIDTHS[i]
        m1, n1 = m // m2, n // n2
        r = min(RANK, m1 * n1, m2 * n2)
        out.append((m1, n1, m2, n2, r))
    return out


def init_state(seed, dt):
    rng = Rng(seed ^ fnv(KEY))
    params = []
    for m1, n1, m2, n2, r in layer_dims():
        a_std = np.sqrt(dt(1.0) / dt(np.float32(r * n1)))
        b_std = np.sqrt(dt(1.0) / dt(np.float32(n2)))
        s = np.ones((m1, n1), dtype=dt)
        a = np.array(
            [rng.normal() for _ in range(r * m1 * n1)], dtype=dt
        ).reshape(r, m1, n1) * dt(a_std)
        b = np.array(
            [rng.normal() for _ in range(r * m2 * n2)], dtype=dt
        ).reshape(r, m2, n2) * dt(b_std)
        params.append(
            dict(S=s, A=a, B=b, vA=np.zeros_like(a), vB=np.zeros_like(b))
        )
    return params


def reconstruct(p, dims):
    m1, n1, m2, n2, r = dims
    w4 = np.einsum("ac,rac,rbd->abcd", p["S"], p["A"], p["B"])
    return w4.reshape(m1 * m2, n1 * n2)


def block_sparsity(w, m2, n2, eps_rel=0.02):
    m, n = w.shape
    w4 = w.reshape(m // m2, m2, n // n2, n2)
    norms = np.sqrt(np.einsum("abcd,abcd->ac", w4, w4))
    rms = np.sqrt(np.mean(norms * norms))
    thr = eps_rel * max(rms, 1e-20)
    return float(np.mean(norms < thr))


def run(dtype_name, lam=LAM, lr=LR):
    dt = np.float32 if dtype_name == "f32" else np.float64
    dims = layer_dims()
    params = init_state(0, dt)
    x_all, y_all = make_data(dt)

    last = None
    for step in range(STEPS):
        lo = (step % (N_DATA // NB)) * NB
        x, y = x_all[lo : lo + NB], y_all[lo : lo + NB]

        ws = [reconstruct(p, d).astype(dt) for p, d in zip(params, dims)]
        acts = [x]
        for li, w in enumerate(ws):
            z = acts[-1] @ w.T
            acts.append(np.maximum(z, 0) if li + 1 < len(ws) else z)
        z = acts[-1]

        zmax = z.max(axis=1, keepdims=True)
        e = np.exp(z - zmax)
        p_soft = e / e.sum(axis=1, keepdims=True)
        ce = float(
            np.mean(np.log(e.sum(axis=1)) + zmax[:, 0] - z[np.arange(NB), y])
        )
        acc = float(np.mean(np.argmax(z, axis=1) == y))
        dz = p_soft.copy()
        dz[np.arange(NB), y] -= 1.0
        dz /= NB

        s_l1 = [float(np.abs(p["S"]).sum()) for p in params]
        loss = ce + lam * sum(s_l1)
        last = dict(loss=loss, ce=ce, acc=acc, s_l1=s_l1)

        dcur = dz
        grads = [None] * len(ws)
        for li in reversed(range(len(ws))):
            xin = acts[li]
            dw = dcur.T @ xin
            m1, n1, m2, n2, r = dims[li]
            dw4 = dw.reshape(m1, m2, n1, n2)
            p = params[li]
            dc = np.einsum("abcd,rbd->rac", dw4, p["B"])
            c = p["S"][None, :, :] * p["A"]
            gb = np.einsum("abcd,rac->rbd", dw4, c)
            ga = dc * p["S"][None, :, :]
            gs = (dc * p["A"]).sum(axis=0)
            grads[li] = (gs, ga, gb)
            if li > 0:
                dx = dcur @ ws[li]
                dcur = dx * (acts[li] > 0)

        for p, (gs, ga, gb) in zip(params, grads):
            p["vA"] = MU * p["vA"] + ga
            p["A"] = p["A"] - dt(lr) * p["vA"]
            p["vB"] = MU * p["vB"] + gb
            p["B"] = p["B"] - dt(lr) * p["vB"]
            s = p["S"] - dt(lr) * gs
            p["S"] = np.sign(s) * np.maximum(np.abs(s) - dt(lr) * dt(lam), 0)

    spars = [
        100.0 * block_sparsity(reconstruct(p, d), d[2], d[3])
        for p, d in zip(params, dims)
    ]
    final_s_l1 = [float(np.abs(p["S"]).sum()) for p in params]
    return last, spars, final_s_l1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dtype", default="f64", choices=["f32", "f64"])
    args = ap.parse_args()
    last, spars, final_s_l1 = run(args.dtype)
    print(f"dtype            : {args.dtype}")
    print(f"spec             : {KEY}  lambda={LAM} lr={LR} steps={STEPS}")
    print(f"final step loss  : {last['loss']:.6f}")
    print(f"final step ce    : {last['ce']:.6f}")
    print(f"final step acc   : {last['acc']:.4f}")
    print(f"pre-update s_l1  : {[round(v, 4) for v in last['s_l1']]}")
    print(f"post-update s_l1 : {[round(v, 4) for v in final_s_l1]}")
    print(f"block sparsity % : {[round(v, 3) for v in spars]}")


if __name__ == "__main__":
    main()
