"""L1 correctness: Pallas kernels vs the pure-jnp oracles.

Hypothesis sweeps shapes/ranks/batch sizes; the kernel must match ref.py
to f32 tolerance everywhere, including non-tile-aligned batches.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.block_sparse import block_sparse_matmul
from compile.kernels.kpd_matmul import (kpd_forward, kpd_forward_mxu_flops,
                                        kpd_forward_schedule,
                                        kpd_forward_vmem_bytes)

RNG = np.random.default_rng(0)


def rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


def assert_close(a, b, tol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# -------------------------------------------------------------- fixed cases

def test_kpd_kernel_matches_ref_basic():
    x, s = rand(32, 64), rand(4, 8)
    a, b = rand(3, 4, 8), rand(3, 2, 8)
    assert_close(kpd_forward(x, s, a, b, tile_n=16), ref.kpd_forward_ref(x, s, a, b))


def test_kpd_ref_matches_dense_reconstruction():
    x, s = rand(8, 64), rand(4, 8)
    a, b = rand(2, 4, 8), rand(2, 2, 8)
    assert_close(ref.kpd_forward_ref(x, s, a, b),
                 ref.kpd_forward_dense_ref(x, s, a, b))


def test_kpd_kernel_rank_one_is_pure_kron():
    x, s = rand(16, 12), np.ones((2, 3), np.float32)
    a, b = rand(1, 2, 3), rand(1, 2, 4)
    w = np.kron(s * a[0], b[0])
    assert_close(kpd_forward(x, s, a, b, tile_n=8), x @ w.T)


def test_kpd_zero_s_gives_zero_output():
    x = rand(8, 16)
    s = np.zeros((2, 2), np.float32)
    a, b = rand(2, 2, 2), rand(2, 4, 8)
    out = np.asarray(kpd_forward(x, s, a, b, tile_n=8))
    assert np.abs(out).max() == 0.0


def test_kpd_unaligned_batch_padding():
    # batch 13 with tile 8 exercises the pad+slice path
    x, s = rand(13, 32), rand(2, 4)
    a, b = rand(2, 2, 4), rand(2, 4, 8)
    assert_close(kpd_forward(x, s, a, b, tile_n=8), ref.kpd_forward_ref(x, s, a, b))


def test_block_sparse_matches_ref():
    w = rand(8, 16)
    mask = (RNG.random((4, 4)) > 0.4).astype(np.float32)
    x = rand(20, 16)
    assert_close(block_sparse_matmul(x, w, mask, m1=4, tile_n=8),
                 ref.block_sparse_matmul_ref(x, w, mask))


def test_block_sparse_full_mask_is_dense():
    w, x = rand(6, 9), rand(10, 9)
    mask = np.ones((2, 3), np.float32)
    assert_close(block_sparse_matmul(x, w, mask, m1=2, tile_n=8), x @ w.T)


def test_block_sparse_empty_mask_is_zero():
    w, x = rand(4, 8), rand(5, 8)
    mask = np.zeros((2, 2), np.float32)
    out = np.asarray(block_sparse_matmul(x, w, mask, m1=2, tile_n=8))
    assert np.abs(out).max() == 0.0


def test_schedule_impl_matches_pallas_and_ref():
    """The straight-line export schedule (BS_KPD_IMPL=schedule, the §Perf
    fast path for the 0.5.1 CPU PJRT) must be bit-for-bit the same math as
    the pallas kernel and the oracle."""
    x, s = rand(21, 48), rand(3, 4)
    a, b = rand(4, 3, 4), rand(4, 2, 12)
    want = ref.kpd_forward_ref(x, s, a, b)
    assert_close(kpd_forward_schedule(x, s, a, b), want)
    assert_close(kpd_forward(x, s, a, b, tile_n=8), want)


# -------------------------------------------------------------- hypothesis

@st.composite
def kpd_shapes(draw):
    m1 = draw(st.sampled_from([1, 2, 4, 5]))
    n1 = draw(st.sampled_from([1, 2, 4, 7]))
    m2 = draw(st.sampled_from([1, 2, 3, 4]))
    n2 = draw(st.sampled_from([1, 2, 4, 8]))
    r = draw(st.integers(1, min(m1 * n1, m2 * n2)))
    n_batch = draw(st.integers(1, 33))
    return n_batch, m1, n1, m2, n2, r


@settings(max_examples=25, deadline=None)
@given(kpd_shapes(), st.integers(0, 2**31 - 1))
def test_kpd_kernel_matches_ref_sweep(shape, seed):
    n_batch, m1, n1, m2, n2, r = shape
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n_batch, n1 * n2)).astype(np.float32)
    s = rng.standard_normal((m1, n1)).astype(np.float32)
    a = rng.standard_normal((r, m1, n1)).astype(np.float32)
    b = rng.standard_normal((r, m2, n2)).astype(np.float32)
    assert_close(kpd_forward(x, s, a, b, tile_n=16), ref.kpd_forward_ref(x, s, a, b),
                 tol=5e-4)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([1, 2, 4]), st.sampled_from([1, 2, 4]),
       st.integers(1, 25), st.integers(0, 2**31 - 1))
def test_block_sparse_sweep(m1, n1, n_batch, seed):
    rng = np.random.default_rng(seed)
    m2, n2 = 3, 5
    w = rng.standard_normal((m1 * m2, n1 * n2)).astype(np.float32)
    mask = (rng.random((m1, n1)) > 0.5).astype(np.float32)
    x = rng.standard_normal((n_batch, n1 * n2)).astype(np.float32)
    assert_close(block_sparse_matmul(x, w, mask, m1=m1, tile_n=8),
                 ref.block_sparse_matmul_ref(x, w, mask), tol=5e-4)


# ----------------------------------------------------------- perf estimators

def test_vmem_estimate_positive_and_monotone():
    small = kpd_forward_vmem_bytes(128, 2, 4, 8, 2, 16)
    big = kpd_forward_vmem_bytes(128, 8, 4, 8, 2, 16)
    assert 0 < small < big


def test_mxu_flops_match_manual():
    # 2·N·r·(n1·n2·m2 + m2·n1·m1)
    got = kpd_forward_mxu_flops(4, 2, 3, 5, 7, 11)
    want = 2 * 4 * 2 * (5 * 11 * 7 + 7 * 5 * 3)
    assert got == want
