"""L2 layer correctness: custom VJP vs jax autodiff, Proposition 1, inits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers
from compile.kernels import ref
from compile.shapes import KPDShape, from_block, optimal_block_r1

RNG = np.random.default_rng(1)


def rand(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def test_custom_vjp_matches_autodiff_of_ref():
    """The hand-written backward (paper Eqs. 19-24) must equal jax's
    autodiff of the einsum reference for every input."""
    x, s = rand(16, 24), rand(3, 4)
    a, b = rand(2, 3, 4), rand(2, 2, 6)
    g = rand(16, 6)

    def loss_kernel(x, s, a, b):
        return (layers.kpd_apply(x, s, a, b) * g).sum()

    def loss_ref(x, s, a, b):
        return (ref.kpd_forward_ref(x, s, a, b) * g).sum()

    got = jax.grad(loss_kernel, argnums=(0, 1, 2, 3))(x, s, a, b)
    want = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, s, a, b)
    for gg, ww in zip(got, want):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww),
                                   rtol=2e-4, atol=2e-4)


def test_proposition1_exact_reconstruction():
    """Prop. 1: every block-wise sparse matrix is representable by Eq. 3
    with r = #nonzero blocks — build the construction and verify."""
    m1, n1, m2, n2 = 3, 4, 2, 5
    rng = np.random.default_rng(7)
    # random block-sparse W with 5 non-zero blocks
    w = np.zeros((m1 * m2, n1 * n2), np.float32)
    nz = [(0, 0), (1, 2), (2, 3), (0, 3), (2, 0)]
    for (i1, j1) in nz:
        w[i1 * m2:(i1 + 1) * m2, j1 * n2:(j1 + 1) * n2] = \
            rng.standard_normal((m2, n2)).astype(np.float32)
    # paper's construction: S binary, A_i one-hot, B_i = block
    r = len(nz)
    s = np.zeros((m1, n1), np.float32)
    a = np.zeros((r, m1, n1), np.float32)
    b = np.zeros((r, m2, n2), np.float32)
    for k, (i1, j1) in enumerate(nz):
        s[i1, j1] = 1.0
        a[k, i1, j1] = 1.0
        b[k] = w[i1 * m2:(i1 + 1) * m2, j1 * n2:(j1 + 1) * n2]
    w_hat = ref.kpd_reconstruct(jnp.asarray(s), jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(w_hat), w, rtol=1e-6, atol=1e-6)


def test_kpd_init_scale():
    """Effective W_r std should be within ~3x of glorot target."""
    shape = from_block(64, 128, (4, 4), 4)
    s, a, b = layers.kpd_init(jax.random.PRNGKey(0), shape)
    w = np.asarray(ref.kpd_reconstruct(s, a, b))
    target = np.sqrt(2.0 / (64 + 128))
    assert target / 4 < w.std() < target * 4, (w.std(), target)


def test_masked_linear_freezes_masked_blocks():
    p = layers.masked_linear_init(jax.random.PRNGKey(0), "l", 4, 8, 2, 2, 0.5)
    mask = np.asarray(p["l.mask"])
    assert mask.shape == (2, 4)
    assert mask.sum() == 4  # density 0.5 of 8 blocks
    x = rand(3, 8)
    y = layers.masked_linear_apply(p, "l", x, 2, 2)
    # zeroed blocks contribute nothing: zero those W blocks manually -> same
    w = np.asarray(p["l.W"]).reshape(2, 2, 4, 2) * mask[:, None, :, None]
    np.testing.assert_allclose(
        np.asarray(y) - np.asarray(p["l.bias"]),
        np.asarray(x) @ w.reshape(4, 8).T, rtol=1e-5, atol=1e-5)


def test_shapes_module():
    s = from_block(10, 784, (2, 16), 2)
    assert (s.m, s.n) == (10, 784)
    assert s.train_params() == 5 * 49 + 2 * (5 * 49 + 32)
    with pytest.raises(ValueError):
        from_block(10, 784, (3, 16), 1)
    # Example 1 optimum
    opt = optimal_block_r1(8, 256)
    assert opt.m1 * opt.n1 == 32


def test_rank_clamp():
    s = from_block(10, 84, (2, 2), 5)
    assert s.r == 4  # min(5*42, 2*2)
