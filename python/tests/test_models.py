"""L2 model zoo: shapes, slot divisibility, determinism, causality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers
from compile import methods as M
from compile.models import (LMConfig, MODELS, ViTConfig, lenet5, linear_model,
                            transformer_lm, vit)

KEY = jax.random.PRNGKey(0)


def dense_params_and_apply(model):
    b = M.dense_method(model)
    params, _ = b.init(KEY)
    return params, lambda p, x: model.apply(p, x, layers.dense_linear_apply)


@pytest.mark.parametrize("name", list(MODELS))
def test_all_models_forward_shapes(name):
    model = MODELS[name]()
    params, apply = dense_params_and_apply(model)
    n = 2
    if model.input_dtype == "i32":
        x = jnp.zeros((n,) + model.input_shape, jnp.int32)
        logits = apply(params, x)
        assert logits.shape == (n, model.input_shape[0], model.num_classes)
    else:
        x = jnp.zeros((n,) + model.input_shape, jnp.float32)
        logits = apply(params, x)
        assert logits.shape == (n, model.num_classes)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name,block", [
    ("linear", (2, 2)), ("linear", (2, 16)),
    ("lenet5", (2, 2)),
    ("vit_micro", (2, 2)), ("vit_micro", (4, 4)), ("vit_micro", (8, 8)),
    ("vit_small", (4, 4)), ("swin_proxy", (4, 4)), ("swin_proxy", (8, 8)),
    ("lm_e2e", (4, 4)),
])
def test_slots_divisible_by_blocks(name, block):
    """Every experiment block size must tile every slot of its model."""
    model = MODELS[name]()
    for s in model.slots:
        assert s.m % block[0] == 0, (name, s.name, s.m, block)
        assert s.n % block[1] == 0, (name, s.name, s.n, block)


def test_lenet_paper_block_combos_tile():
    from compile.specs import LENET_COMBOS
    model = lenet5()
    dims = {s.name: (s.m, s.n) for s in model.slots}
    for _, combo in LENET_COMBOS:
        for slot, (m2, n2) in combo.items():
            m, n = dims[slot]
            assert m % m2 == 0 and n % n2 == 0, (slot, (m2, n2), (m, n))


def test_lenet_fc_dims_match_paper():
    model = lenet5()
    got = {(s.name): (s.m, s.n) for s in model.slots}
    assert got == {"fc1": (120, 400), "fc2": (84, 120), "fc3": (10, 84)}


def test_vit_seq_and_patch_dims():
    cfg = ViTConfig(dim=64, depth=2, heads=4)
    assert cfg.seq == 65
    assert cfg.patch_dim == 48
    model = vit(cfg)
    assert len(model.slots) == 8  # 4 per block × 2


def test_lm_causality():
    """Changing a future token must not affect earlier logits."""
    cfg = LMConfig(vocab=32, dim=32, depth=1, heads=2, seq=8)
    model = transformer_lm(cfg)
    params, apply = dense_params_and_apply(model)
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, 32, (1, 8), dtype=np.int32)
    t2 = t1.copy()
    t2[0, 6] = (t2[0, 6] + 1) % 32
    l1 = np.asarray(apply(params, jnp.asarray(t1)))
    l2 = np.asarray(apply(params, jnp.asarray(t2)))
    np.testing.assert_allclose(l1[0, :6], l2[0, :6], rtol=1e-4, atol=1e-5)
    assert np.abs(l1[0, 6:] - l2[0, 6:]).max() > 1e-6


def test_model_apply_deterministic():
    model = MODELS["vit_micro"]()
    params, apply = dense_params_and_apply(model)
    x = jnp.asarray(np.random.default_rng(1)
                    .standard_normal((2, 3072)).astype(np.float32))
    a = np.asarray(apply(params, x))
    b = np.asarray(apply(params, x))
    np.testing.assert_array_equal(a, b)


def test_linear_model_is_one_slot():
    model = linear_model()
    assert len(model.slots) == 1
    assert (model.slots[0].m, model.slots[0].n) == (10, 784)
