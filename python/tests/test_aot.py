"""AOT pipeline: lowering produces parseable HLO text and a manifest whose
input ordering matches jax's pytree flatten order (the contract the rust
runtime depends on)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import methods as M
from compile.models import linear_model
from compile.specs import build_specs, spec_by_key


def test_spec_keys_unique():
    specs = build_specs()
    keys = [s.key for s in specs]
    assert len(keys) == len(set(keys))
    assert spec_by_key("t1_kpd_b2x2").model_name == "linear"
    with pytest.raises(KeyError):
        spec_by_key("nope")


def test_every_table_has_specs():
    specs = build_specs()
    tags = {t for s in specs for t in s.tags}
    for required in ("table1", "table2", "table3", "table4",
                     "fig3a", "fig3b", "fig3c", "e2e", "quickstart"):
        assert required in tags, f"no specs for {required}"


def test_sorted_keys_equals_tree_flatten_order():
    """The manifest records dict keys in sorted order; jax flattens dicts
    in sorted-key order. If either side changes, the PJRT argument order
    breaks — pin it here."""
    d = {"b": jnp.zeros(1), "a.x": jnp.zeros(2), "a!y": jnp.zeros(3)}
    leaves, _ = jax.tree_util.tree_flatten(d)
    sizes_by_sorted = [d[k].size for k in sorted(d)]
    assert [l.size for l in leaves] == sizes_by_sorted


def test_lowering_roundtrip(tmp_path):
    model = linear_model()
    bundle = M.kpd_method(model, M.uniform_blocks(model, (2, 4)), rank=1)
    em = aot.Emitter(str(tmp_path))
    import compile.specs as S
    meta = aot.lower_spec(S.Spec("tst", "linear", 8,
                                 lambda m: M.kpd_method(
                                     m, M.uniform_blocks(m, (2, 4)), rank=1),
                                 ("t",)), em)
    # all five standard files for a kpd spec
    names = {e["exec"] for e in em.entries}
    assert names == {"init", "train_step", "eval_step", "materialize"}
    for e in em.entries:
        path = tmp_path / e["file"]
        text = path.read_text()
        assert text.startswith("HloModule"), e["file"]
        assert "ENTRY" in text
        # arity sanity
        assert len(e["inputs"]) >= 1 and len(e["outputs"]) >= 1
    # train_step IO: params+opt+x+y+hyper -> params+opt+metrics
    ts = next(e for e in em.entries if e["exec"] == "train_step")
    in_params = [i for i in ts["inputs"] if i["name"].startswith("param:")]
    out_params = [o for o in ts["outputs"] if o["name"].startswith("param:")]
    assert [i["name"] for i in in_params] == [o["name"] for o in out_params]
    assert ts["inputs"][-2]["name"] == "lambda"
    assert ts["inputs"][-1]["name"] == "lr"
    assert ts["outputs"][-1]["name"] == "metrics"
    assert meta["method"] == "kpd"
    assert meta["params_total"] > 0


def test_manifest_on_disk_if_built():
    """When artifacts/ exists (make artifacts), validate global invariants."""
    mpath = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                         "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        m = json.load(f)
    keys = {s["key"] for s in m["specs"]}
    execs = {(e["spec"], e["exec"]) for e in m["executables"]}
    # every spec has at least init/train/eval
    for k in keys:
        for ex in ("init", "train_step", "eval_step"):
            assert (k, ex) in execs, (k, ex)
    # every executable file exists
    adir = os.path.dirname(mpath)
    for e in m["executables"]:
        assert os.path.exists(os.path.join(adir, e["file"])), e["file"]
    # input/output param names agree for train steps
    for e in m["executables"]:
        if e["exec"] != "train_step":
            continue
        ip = [i["name"] for i in e["inputs"] if i["name"].startswith("param:")]
        op = [o["name"] for o in e["outputs"] if o["name"].startswith("param:")]
        assert ip == op, e["spec"]
