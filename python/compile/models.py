"""L2 model zoo: every architecture in the paper's evaluation.

Each model is a ``ModelDef`` that separates the *backbone* from the
*factorizable linear slots*. The backbone (convs, embeddings, layer norms,
heads) is always dense; the slots are the layers the paper factorizes /
sparsifies (the 1 linear layer of §6.1, the 3 FC layers of LeNet-5 §6.2,
every transformer linear in §6.3). methods.py plugs in the per-method
parameterization (KPD / dense+group-lasso / masked-RigL) via the
``linear_apply`` callback, so one backbone serves all five methods.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import layers

Params = Dict[str, jnp.ndarray]
# (params, slot_name, x) -> y ; shape of the slot is fixed at init time.
LinearApply = Callable[[Params, str, jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Slot:
    """A factorizable linear layer: y = x W^T (+bias), W ∈ R^{m×n}."""
    name: str
    m: int
    n: int


@dataclasses.dataclass(frozen=True)
class ModelDef:
    name: str
    input_shape: Tuple[int, ...]          # per-example, e.g. (784,) or (3,32,32)
    num_classes: int
    slots: Tuple[Slot, ...]
    init_extra: Callable[[jax.Array], Params]
    apply: Callable[[Params, jnp.ndarray, LinearApply], jnp.ndarray]
    input_dtype: str = "f32"              # "f32" images | "i32" tokens


# ---------------------------------------------------------------- linear

def linear_model(in_dim: int = 784, classes: int = 10) -> ModelDef:
    """§6.1: one linear layer + softmax on (synthetic) MNIST."""
    slot = Slot("fc", classes, in_dim)

    def init_extra(key) -> Params:
        return {}

    def apply(params: Params, x: jnp.ndarray, lin: LinearApply) -> jnp.ndarray:
        return lin(params, "fc", x.reshape(x.shape[0], -1))

    return ModelDef("linear", (in_dim,), classes, (slot,), init_extra, apply)


# ---------------------------------------------------------------- LeNet-5

def _conv(x, w, b, padding):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + b[None, :, None, None]


def _avgpool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID") * 0.25


def lenet5(classes: int = 10) -> ModelDef:
    """§6.2: LeNet-5 on 28×28; only the three FC layers (400→120→84→10)
    are factorized, matching the paper ("the column block size indicated
    the block sizes for these [three fully connected] layers")."""
    slots = (Slot("fc1", 120, 400), Slot("fc2", 84, 120), Slot("fc3", classes, 84))

    def init_extra(key) -> Params:
        k1, k2 = jax.random.split(key)
        return {
            "conv1.W": layers.glorot(k1, (6, 1, 5, 5), 25, 150),
            "conv1.bias": jnp.zeros((6,), jnp.float32),
            "conv2.W": layers.glorot(k2, (16, 6, 5, 5), 150, 400),
            "conv2.bias": jnp.zeros((16,), jnp.float32),
        }

    def apply(params: Params, x: jnp.ndarray, lin: LinearApply) -> jnp.ndarray:
        h = x.reshape(x.shape[0], 1, 28, 28)
        h = jax.nn.relu(_conv(h, params["conv1.W"], params["conv1.bias"], "SAME"))
        h = _avgpool2(h)                                   # (N, 6, 14, 14)
        h = jax.nn.relu(_conv(h, params["conv2.W"], params["conv2.bias"], "VALID"))
        h = _avgpool2(h)                                   # (N, 16, 5, 5)
        h = h.reshape(h.shape[0], 400)
        h = jax.nn.relu(lin(params, "fc1", h))
        h = jax.nn.relu(lin(params, "fc2", h))
        return lin(params, "fc3", h)

    return ModelDef("lenet5", (784,), classes, slots, init_extra, apply)


# ---------------------------------------------------------------- ViT

@dataclasses.dataclass(frozen=True)
class ViTConfig:
    """Width/depth-scaled ViT. The paper trains ViT-tiny (dim 192, depth 12)
    / ViT-base on CIFAR-100; this CPU testbed uses the same architecture at
    reduced dim/depth (DESIGN.md §5 substitution) — all linear slots keep
    dimensions divisible by the 2/4/8 block sizes used in §6.3."""
    dim: int = 64
    depth: int = 2
    heads: int = 4
    mlp_ratio: int = 2
    patch: int = 4
    image: int = 32
    chans: int = 3
    classes: int = 100

    @property
    def seq(self) -> int:
        return (self.image // self.patch) ** 2 + 1  # +1 cls token

    @property
    def patch_dim(self) -> int:
        return self.chans * self.patch * self.patch


def _attention(q, k, v, heads: int) -> jnp.ndarray:
    n, t, d = q.shape
    hd = d // heads
    def split(x):
        return x.reshape(n, t, heads, hd).transpose(0, 2, 1, 3)
    qh, kh, vh = split(q), split(k), split(v)
    att = jnp.einsum("nhtd,nhsd->nhts", qh, kh) / jnp.sqrt(jnp.float32(hd))
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("nhts,nhsd->nhtd", att, vh)
    return out.transpose(0, 2, 1, 3).reshape(n, t, d)


def vit(cfg: ViTConfig) -> ModelDef:
    """§6.3: ViT with every block linear (qkv / proj / mlp1 / mlp2)
    factorizable. Patch embed + head stay dense (head rows = 100 classes,
    not divisible by the 8×8 pattern-selection candidate)."""
    d, mlp = cfg.dim, cfg.dim * cfg.mlp_ratio
    slots: List[Slot] = []
    for i in range(cfg.depth):
        slots += [Slot(f"blk{i}.qkv", 3 * d, d), Slot(f"blk{i}.proj", d, d),
                  Slot(f"blk{i}.mlp1", mlp, d), Slot(f"blk{i}.mlp2", d, mlp)]

    def init_extra(key) -> Params:
        keys = jax.random.split(key, 3 + cfg.depth)
        p: Params = {
            "embed.W": layers.glorot(keys[0], (d, cfg.patch_dim), cfg.patch_dim, d),
            "embed.bias": jnp.zeros((d,), jnp.float32),
            "cls": jax.random.normal(keys[1], (1, 1, d), jnp.float32) * 0.02,
            "pos": jax.random.normal(keys[2], (1, cfg.seq, d), jnp.float32) * 0.02,
            "head.W": layers.glorot(keys[3], (cfg.classes, d), d, cfg.classes),
            "head.bias": jnp.zeros((cfg.classes,), jnp.float32),
        }
        for i in range(cfg.depth):
            p[f"blk{i}.ln1.g"] = jnp.ones((d,), jnp.float32)
            p[f"blk{i}.ln1.b"] = jnp.zeros((d,), jnp.float32)
            p[f"blk{i}.ln2.g"] = jnp.ones((d,), jnp.float32)
            p[f"blk{i}.ln2.b"] = jnp.zeros((d,), jnp.float32)
        p["ln_f.g"] = jnp.ones((d,), jnp.float32)
        p["ln_f.b"] = jnp.zeros((d,), jnp.float32)
        return p

    def apply(params: Params, x: jnp.ndarray, lin: LinearApply) -> jnp.ndarray:
        n = x.shape[0]
        img = x.reshape(n, cfg.chans, cfg.image, cfg.image)
        g = cfg.image // cfg.patch
        patches = img.reshape(n, cfg.chans, g, cfg.patch, g, cfg.patch)
        patches = patches.transpose(0, 2, 4, 1, 3, 5).reshape(n, g * g, cfg.patch_dim)
        h = patches @ params["embed.W"].T + params["embed.bias"]
        h = jnp.concatenate([jnp.tile(params["cls"], (n, 1, 1)), h], axis=1)
        h = h + params["pos"]
        t = h.shape[1]

        def lin2d(pp, name, z):          # slots see (N·T, d) matrices
            return lin(pp, name, z.reshape(n * t, -1)).reshape(n, t, -1)

        for i in range(cfg.depth):
            z = layers.layer_norm(h, params[f"blk{i}.ln1.g"], params[f"blk{i}.ln1.b"])
            qkv = lin2d(params, f"blk{i}.qkv", z)
            q, k, v = jnp.split(qkv, 3, axis=-1)
            att = _attention(q, k, v, cfg.heads)
            h = h + lin2d(params, f"blk{i}.proj", att)
            z = layers.layer_norm(h, params[f"blk{i}.ln2.g"], params[f"blk{i}.ln2.b"])
            z = jax.nn.gelu(lin2d(params, f"blk{i}.mlp1", z))
            h = h + lin2d(params, f"blk{i}.mlp2", z)

        h = layers.layer_norm(h, params["ln_f.g"], params["ln_f.b"])
        cls = h[:, 0]
        return cls @ params["head.W"].T + params["head.bias"]

    flat = cfg.chans * cfg.image * cfg.image
    return ModelDef(f"vit_d{cfg.dim}x{cfg.depth}", (flat,), cfg.classes,
                    tuple(slots), init_extra, apply)


# ---------------------------------------------------------------- LM (E2E)

@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Decoder-only LM for the end-to-end training example. vocab 64
    keeps the bigram/trigram structure learnable within a CPU-budget run
    (a 256-way softmax needs far more steps to beat the uniform bound)."""
    vocab: int = 64
    dim: int = 192
    depth: int = 4
    heads: int = 4
    mlp_ratio: int = 4
    seq: int = 128


def _causal_attention(q, k, v, heads: int) -> jnp.ndarray:
    n, t, d = q.shape
    hd = d // heads
    def split(x):
        return x.reshape(n, t, heads, hd).transpose(0, 2, 1, 3)
    qh, kh, vh = split(q), split(k), split(v)
    att = jnp.einsum("nhtd,nhsd->nhts", qh, kh) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.tril(jnp.ones((t, t), jnp.float32))
    att = jnp.where(causal[None, None] > 0, att, jnp.float32(-1e9))
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("nhts,nhsd->nhtd", att, vh)
    return out.transpose(0, 2, 1, 3).reshape(n, t, d)


def transformer_lm(cfg: LMConfig) -> ModelDef:
    """Next-token LM; all block linears factorizable, embeddings dense.
    "num_classes" is the vocab (logits are per-position; the train step
    flattens (N,T,V) before the CE)."""
    d, mlp = cfg.dim, cfg.dim * cfg.mlp_ratio
    slots: List[Slot] = []
    for i in range(cfg.depth):
        slots += [Slot(f"blk{i}.qkv", 3 * d, d), Slot(f"blk{i}.proj", d, d),
                  Slot(f"blk{i}.mlp1", mlp, d), Slot(f"blk{i}.mlp2", d, mlp)]

    def init_extra(key) -> Params:
        k1, k2, k3 = jax.random.split(key, 3)
        p: Params = {
            "tok": jax.random.normal(k1, (cfg.vocab, d), jnp.float32) * 0.02,
            "pos": jax.random.normal(k2, (1, cfg.seq, d), jnp.float32) * 0.02,
            "head.W": layers.glorot(k3, (cfg.vocab, d), d, cfg.vocab),
            "head.bias": jnp.zeros((cfg.vocab,), jnp.float32),
        }
        for i in range(cfg.depth):
            p[f"blk{i}.ln1.g"] = jnp.ones((d,), jnp.float32)
            p[f"blk{i}.ln1.b"] = jnp.zeros((d,), jnp.float32)
            p[f"blk{i}.ln2.g"] = jnp.ones((d,), jnp.float32)
            p[f"blk{i}.ln2.b"] = jnp.zeros((d,), jnp.float32)
        p["ln_f.g"] = jnp.ones((d,), jnp.float32)
        p["ln_f.b"] = jnp.zeros((d,), jnp.float32)
        return p

    def apply(params: Params, tokens: jnp.ndarray, lin: LinearApply) -> jnp.ndarray:
        n, t = tokens.shape
        h = params["tok"][tokens.astype(jnp.int32)] + params["pos"][:, :t]

        def lin2d(pp, name, z):
            return lin(pp, name, z.reshape(n * t, -1)).reshape(n, t, -1)

        for i in range(cfg.depth):
            z = layers.layer_norm(h, params[f"blk{i}.ln1.g"], params[f"blk{i}.ln1.b"])
            qkv = lin2d(params, f"blk{i}.qkv", z)
            q, k, v = jnp.split(qkv, 3, axis=-1)
            h = h + lin2d(params, f"blk{i}.proj", _causal_attention(q, k, v, cfg.heads))
            z = layers.layer_norm(h, params[f"blk{i}.ln2.g"], params[f"blk{i}.ln2.b"])
            h = h + lin2d(params, f"blk{i}.mlp2", jax.nn.gelu(lin2d(params, f"blk{i}.mlp1", z)))

        h = layers.layer_norm(h, params["ln_f.g"], params["ln_f.b"])
        return h @ params["head.W"].T + params["head.bias"]

    return ModelDef(f"lm_d{cfg.dim}x{cfg.depth}", (cfg.seq,), cfg.vocab,
                    tuple(slots), init_extra, apply, input_dtype="i32")


MODELS = {
    "linear": lambda: linear_model(),
    "lenet5": lambda: lenet5(),
    "vit_micro": lambda: vit(ViTConfig(dim=64, depth=2, heads=4)),
    "vit_small": lambda: vit(ViTConfig(dim=128, depth=4, heads=4)),
    "swin_proxy": lambda: vit(ViTConfig(dim=96, depth=3, heads=3, mlp_ratio=4)),
    "lm_micro": lambda: transformer_lm(LMConfig(dim=96, depth=2, seq=64)),
    "lm_e2e": lambda: transformer_lm(LMConfig(dim=192, depth=4, seq=128)),
}
