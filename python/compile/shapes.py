"""Shape algebra for the KPD (Kronecker-product-decomposition) factorization.

The paper (Eq. 3) estimates a weight matrix ``W ∈ R^{m×n}`` by

    W_r = sum_{i=1..r} (S ⊙ A_i) ⊗ B_i

with ``S, A_i ∈ R^{m1×n1}``, ``B_i ∈ R^{m2×n2}``, ``m = m1·m2``, ``n = n1·n2``.
The *block size* of the resulting block-wise sparse matrix is ``(m2, n2)``
and the number of blocks is ``m1 × n1`` (one entry of ``S`` per block).

This module is the single source of truth for:
  * legal factorizations of a given (m, n),
  * parameter counts (paper §4, Example 1),
  * the Eq. 5 "minimum parameters" block-size optimizer
    (mirrored in rust/src/blockopt for the runtime side).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class KPDShape:
    """A concrete factorization of an (m, n) weight matrix.

    ``(m1, n1)`` is the grid of blocks (and the shape of S and every A_i);
    ``(m2, n2)`` is the block size (and the shape of every B_i);
    ``r`` is the rank of the Kronecker decomposition.
    """

    m1: int
    n1: int
    m2: int
    n2: int
    r: int

    @property
    def m(self) -> int:
        return self.m1 * self.m2

    @property
    def n(self) -> int:
        return self.n1 * self.n2

    @property
    def block(self) -> Tuple[int, int]:
        return (self.m2, self.n2)

    @property
    def grid(self) -> Tuple[int, int]:
        return (self.m1, self.n1)

    def train_params(self) -> int:
        """Trainable parameter count of the factorized layer (no bias):
        S (m1·n1) + r·(A: m1·n1 + B: m2·n2)."""
        return self.m1 * self.n1 + self.r * (self.m1 * self.n1 + self.m2 * self.n2)

    def dense_params(self) -> int:
        return self.m * self.n

    def validate(self) -> None:
        if self.m1 <= 0 or self.n1 <= 0 or self.m2 <= 0 or self.n2 <= 0:
            raise ValueError(f"non-positive factor in {self}")
        if self.r <= 0:
            raise ValueError(f"rank must be positive, got {self.r}")
        rmax = min(self.m1 * self.n1, self.m2 * self.n2)
        if self.r > rmax:
            raise ValueError(f"rank {self.r} exceeds max {rmax} for {self}")


def divisors(x: int) -> List[int]:
    """All positive divisors of x, ascending."""
    if x <= 0:
        raise ValueError("divisors of non-positive integer")
    small, large = [], []
    d = 1
    while d * d <= x:
        if x % d == 0:
            small.append(d)
            if d != x // d:
                large.append(x // d)
        d += 1
    return small + large[::-1]


def from_block(m: int, n: int, block: Tuple[int, int], r: int,
               clamp_rank: bool = True) -> KPDShape:
    """Build the KPDShape for a given weight shape and block size (m2, n2).

    With ``clamp_rank`` (default), r is capped at min(m1·n1, m2·n2) — the
    exact-decomposition rank bound of Eq. 2; any larger r is redundant
    (Proposition 1 needs at most the number of non-zero blocks)."""
    m2, n2 = block
    if m % m2 != 0 or n % n2 != 0:
        raise ValueError(f"block {block} does not tile ({m}, {n})")
    m1, n1 = m // m2, n // n2
    if clamp_rank:
        r = min(r, m1 * n1, m2 * n2)
    s = KPDShape(m1=m1, n1=n1, m2=m2, n2=n2, r=r)
    s.validate()
    return s


def enumerate_blocks(m: int, n: int, include_trivial: bool = False) -> List[Tuple[int, int]]:
    """All legal block sizes (m2, n2) for an m×n matrix.

    Matches the paper's §5 counting: for a 10×10 matrix there are 14
    non-trivial block sizes (excluding 1×1 and 10×10 and ... exactly the
    divisor-pair grid minus the trivial ones).
    """
    blocks = []
    for m2 in divisors(m):
        for n2 in divisors(n):
            if not include_trivial and (m2, n2) in ((1, 1), (m, n)):
                continue
            blocks.append((m2, n2))
    return blocks


def optimal_block_r1(m: int, n: int) -> KPDShape:
    """Eq. 5: minimize 2·m1·n1 + m2·n2 s.t. m1·m2 = m, n1·n2 = n, r = 1.

    Continuous optimum is m1·n1 = sqrt(mn/2); we branch-and-bound over the
    (finite) divisor grid, which is exact.
    """
    best = None
    best_cost = math.inf
    for m1 in divisors(m):
        for n1 in divisors(n):
            cost = 2 * m1 * n1 + (m // m1) * (n // n1)
            if cost < best_cost:
                best_cost = cost
                best = KPDShape(m1=m1, n1=n1, m2=m // m1, n2=n // n1, r=1)
    assert best is not None
    return best


def reconstruction_rank(m1: int, n1: int) -> int:
    """Rank sufficient to represent ANY block-wise sparse matrix exactly
    (Proposition 1): one (A_i, B_i) pair per non-zero block, worst case
    all m1·n1 blocks non-zero."""
    return m1 * n1
