"""Experiment spec registry: every (model × method × hyper) pair the tables
and figures need, mapped to AOT artifact names.

Block-size label convention: the paper writes linear-model blocks as
"(16, 2)" etc. For the 10×784 linear layer a 16-row block cannot tile 10
rows, so (as in the authors' released configs) the label "(a, b)" denotes a
block of **b output rows × a input columns**, i.e. (m2, n2) = (b, a). The
same reading makes every LeNet combo tile exactly: e.g. (16,8) on the
120×400 fc1 is (m2, n2) = (8, 16) → grid 15×25. Transformer blocks are
square so the convention is invisible there.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from . import methods as M
from .models import MODELS, ModelDef


@dataclasses.dataclass(frozen=True)
class Spec:
    """One AOT bundle: a (model, method) pair at a fixed batch size."""
    key: str                       # artifact base name, e.g. t1_kpd_b2x2
    model_name: str
    batch: int
    build: Callable[[ModelDef], M.MethodBundle]
    tags: Tuple[str, ...] = ()     # table/figure ids this spec serves


def paper_block(label: Tuple[int, int]) -> Tuple[int, int]:
    """(a, b) paper label → (m2, n2) = (b, a)."""
    a, b = label
    return (b, a)


def _lenet_blocks(l1, l2, l3) -> Dict[str, Tuple[int, int]]:
    return {"fc1": paper_block(l1), "fc2": paper_block(l2), "fc3": paper_block(l3)}


LENET_COMBOS: List[Tuple[str, Dict[str, Tuple[int, int]]]] = [
    ("16x8_8x4_4x2", _lenet_blocks((16, 8), (8, 4), (4, 2))),
    ("8x4_4x4_2x2", _lenet_blocks((8, 4), (4, 4), (2, 2))),
    ("4x4_4x4_2x2", _lenet_blocks((4, 4), (4, 4), (2, 2))),
    ("4x4_2x2_2x2", _lenet_blocks((4, 4), (2, 2), (2, 2))),
    ("2x2_2x2_2x2", _lenet_blocks((2, 2), (2, 2), (2, 2))),
]

LINEAR_BLOCK_LABELS: List[Tuple[int, int]] = [(2, 2), (4, 2), (8, 2), (16, 2)]

T1_BATCH = 128
T2_BATCH = 64
T3_BATCH = 32
LM_BATCH = 8


def build_specs() -> List[Spec]:
    specs: List[Spec] = []

    def add(key, model_name, batch, build, tags):
        specs.append(Spec(key, model_name, batch, build, tuple(tags)))

    # ---------------- Table 1: linear on MNIST-like ----------------
    for (a, b) in LINEAR_BLOCK_LABELS:
        blk = paper_block((a, b))
        bk = f"b{a}x{b}"
        add(f"t1_kpd_{bk}", "linear", T1_BATCH,
            lambda m, blk=blk: M.kpd_method(m, M.uniform_blocks(m, blk), rank=2),
            ["table1"])
        add(f"t1_gl_{bk}", "linear", T1_BATCH,
            lambda m, blk=blk: M.group_lasso_method(m, M.uniform_blocks(m, blk)),
            ["table1"])
        add(f"t1_egl_{bk}", "linear", T1_BATCH,
            lambda m, blk=blk: M.group_lasso_method(m, M.uniform_blocks(m, blk), elastic=True),
            ["table1"])
        add(f"t1_rigl_{bk}", "linear", T1_BATCH,
            lambda m, blk=blk: M.rigl_method(m, M.uniform_blocks(m, blk), density=0.5),
            ["table1"])
    add("t1_dense", "linear", T1_BATCH, lambda m: M.dense_method(m), ["table1"])
    add("t1_prune", "linear", T1_BATCH, lambda m: M.iter_prune_method(m), ["table1"])
    # Figure 3a: pattern selection over the four Table-1 blocks + (2,4)
    lin_patterns = [M.uniform_blocks(MODELS["linear"](), paper_block(lbl))
                    for lbl in LINEAR_BLOCK_LABELS]
    add("f3a_pattern", "linear", T1_BATCH,
        lambda m, pats=lin_patterns: M.pattern_method(m, pats, rank=2), ["fig3a"])

    # ---------------- Table 2: LeNet-5 ----------------
    for name, blocks in LENET_COMBOS:
        add(f"t2_kpd_{name}", "lenet5", T2_BATCH,
            lambda m, bl=blocks: M.kpd_method(m, bl, rank=5), ["table2"])
        add(f"t2_gl_{name}", "lenet5", T2_BATCH,
            lambda m, bl=blocks: M.group_lasso_method(m, bl), ["table2"])
        add(f"t2_egl_{name}", "lenet5", T2_BATCH,
            lambda m, bl=blocks: M.group_lasso_method(m, bl, elastic=True), ["table2"])
        add(f"t2_rigl_{name}", "lenet5", T2_BATCH,
            lambda m, bl=blocks: M.rigl_method(m, bl, density=0.5), ["table2"])
    add("t2_dense", "lenet5", T2_BATCH, lambda m: M.dense_method(m), ["table2"])
    add("t2_prune", "lenet5", T2_BATCH, lambda m: M.iter_prune_method(m), ["table2"])
    lenet_patterns = [bl for _, bl in LENET_COMBOS]
    add("f3b_pattern", "lenet5", T2_BATCH,
        lambda m, pats=lenet_patterns: M.pattern_method(m, pats, rank=5), ["fig3b"])

    # ---------------- Table 3: transformers (scaled, see DESIGN §5) -----
    for mname, tag in (("vit_micro", "vit_t"), ("vit_small", "vit_b"),
                       ("swin_proxy", "swin_t")):
        add(f"t3_{tag}_dense", mname, T3_BATCH, lambda m: M.dense_method(m), ["table3"])
        add(f"t3_{tag}_gl", mname, T3_BATCH,
            lambda m: M.group_lasso_method(m, M.uniform_blocks(m, (4, 4))), ["table3"])
        add(f"t3_{tag}_egl", mname, T3_BATCH,
            lambda m: M.group_lasso_method(m, M.uniform_blocks(m, (4, 4)), elastic=True),
            ["table3"])
        add(f"t3_{tag}_rigl", mname, T3_BATCH,
            lambda m: M.rigl_method(m, M.uniform_blocks(m, (4, 4)), density=0.5),
            ["table3"])
        add(f"t3_{tag}_kpd", mname, T3_BATCH,
            lambda m: M.kpd_method(m, M.uniform_blocks(m, (4, 4)), rank=4), ["table3"])
    # Figure 3c: ViT pattern selection over 2×2 / 4×4 / 8×8
    vit_patterns = [M.uniform_blocks(MODELS["vit_micro"](), (bs, bs)) for bs in (2, 4, 8)]
    add("f3c_pattern", "vit_micro", T3_BATCH,
        lambda m, pats=vit_patterns: M.pattern_method(m, pats, rank=4), ["fig3c"])

    # ---------------- Table 4: rank ablation ----------------
    for r in (1, 2, 4, 6):
        add(f"t4_linear_r{r}", "linear", T1_BATCH,
            lambda m, r=r: M.kpd_method(m, M.uniform_blocks(m, paper_block((4, 2))), rank=r),
            ["table4"])
    for mname, tag in (("vit_micro", "vit_t"), ("swin_proxy", "swin_t")):
        for r in (1, 2, 4):
            add(f"t4_{tag}_r{r}", mname, T3_BATCH,
                lambda m, r=r: M.kpd_method(m, M.uniform_blocks(m, (4, 4)), rank=r),
                ["table4"])

    # ---------------- E2E transformer-LM driver ----------------
    add("e2e_lm_kpd", "lm_e2e", LM_BATCH,
        lambda m: M.kpd_method(m, M.uniform_blocks(m, (4, 4)), rank=4, optimizer="adam"),
        ["e2e"])
    add("e2e_lm_dense", "lm_e2e", LM_BATCH,
        lambda m: M.dense_method(m, optimizer="adam"), ["e2e"])
    # small LM used by the integration tests
    add("it_lm_kpd", "lm_micro", 4,
        lambda m: M.kpd_method(m, M.uniform_blocks(m, (4, 4)), rank=2, optimizer="adam"),
        ["itest"])

    # quickstart example artifacts (tiny, compile fast)
    add("qs_kpd", "linear", 32,
        lambda m: M.kpd_method(m, M.uniform_blocks(m, (2, 4)), rank=2), ["quickstart"])

    return specs


def spec_by_key(key: str) -> Spec:
    for s in build_specs():
        if s.key == key:
            return s
    raise KeyError(key)
