"""L2 training methods: ours (KPD) + every baseline in the paper's tables.

A *method* fixes the parameterization of a model's linear slots and the
training objective:

  kpd          — ours: Eq. 3 factorization, CE + λ‖S‖₁       (paper Eq. 4)
  dense        — original uncompressed model (Table 3 "Original Model")
  group_lasso  — dense W + λ Σ_g ‖W_g‖_F                     (paper Eq. 1)
  elastic_gl   — group lasso + ℓ2 (elastic group LASSO baseline)
  rigl_block   — blockwise RigL: frozen block mask, dense-gradient grow
                 signal; mask updates run in a separate executable driven
                 by the rust coordinator every ΔT steps
  iter_prune   — unstructured iterative magnitude pruning (Han et al. '15):
                 train → prune → fine-tune rounds, prune as an executable
  pattern      — pattern selection over K block-size candidates (Eq. 7)

Every method exposes pure functions (no python state) so the whole train
step AOT-lowers to one HLO module:

  train_step(params, opt, x, y, *hyper) -> (params', opt', metrics)
  eval_step(params, x, y)               -> metrics
  plus method-specific executables (rigl_update, prune, materialize).

``metrics`` is a flat f32 vector; names are recorded in the manifest.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import layers, losses, optim
from .models import ModelDef, Slot
from .shapes import KPDShape, from_block

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass
class MethodBundle:
    """Everything the AOT pipeline needs to lower one (model, method) pair."""
    name: str
    model: ModelDef
    init: Callable[[jax.Array], Tuple[Params, Params]]   # -> (params, opt)
    train_step: Callable[..., Tuple[Params, Params, jnp.ndarray]]
    eval_step: Callable[[Params, jnp.ndarray, jnp.ndarray], jnp.ndarray]
    train_hyper: Tuple[str, ...]          # scalar f32 inputs after (x, y)
    metric_names: Tuple[str, ...]
    # optional extra executables: name -> (fn, input spec builder)
    extras: Dict[str, Callable] = dataclasses.field(default_factory=dict)
    # static description merged into the manifest (block sizes, rank, …)
    info: Dict[str, object] = dataclasses.field(default_factory=dict)


def _ce_and_count(model: ModelDef, lin, params, x, y):
    logits = model.apply(params, x, lin)
    if logits.ndim == 3:          # LM: (N, T, V) with per-position targets
        logits = logits.reshape(-1, logits.shape[-1])
        y = y.reshape(-1)
    return losses.cross_entropy(logits, y), losses.accuracy_count(logits, y)


def _make_eval(model: ModelDef, lin):
    def eval_step(params: Params, x, y) -> jnp.ndarray:
        ce, acc = _ce_and_count(model, lin, params, x, y)
        return jnp.stack([ce, acc])
    return eval_step


def _opt(optname: str):
    return optim.OPTIMIZERS[optname]


# =========================================================== ours: KPD

def kpd_method(model: ModelDef, block_map: Dict[str, Tuple[int, int]],
               rank: int, optimizer: str = "sgd") -> MethodBundle:
    """The paper's method. ``block_map`` gives the (m2, n2) block size per
    slot; the factorization grid follows from the slot's (m, n)."""
    shapes: Dict[str, KPDShape] = {
        s.name: from_block(s.m, s.n, block_map[s.name], rank)
        for s in model.slots
    }
    oinit, oupd = _opt(optimizer)

    lin = layers.kpd_linear_apply

    def init(key):
        keys = jax.random.split(key, len(model.slots) + 1)
        params = dict(model.init_extra(keys[0]))
        for i, s in enumerate(model.slots):
            params.update(layers.kpd_linear_init(keys[i + 1], s.name, shapes[s.name]))
        return params, oinit(params)

    def loss_fn(params, x, y, lam):
        ce, acc = _ce_and_count(model, lin, params, x, y)
        reg = losses.kpd_l1(params, lam)
        return ce + reg, (ce, acc, reg)

    def train_step(params, opt, x, y, lam, lr):
        (total, (ce, acc, reg)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, x, y, lam)
        params, opt = oupd(params, grads, opt, lr)
        s_l1 = losses.kpd_l1(params, jnp.float32(1.0))
        return params, opt, jnp.stack([total, ce, acc, reg, s_l1])

    def materialize(params):
        """Reconstruct the block-wise sparse W per slot (inference path /
        sparsity measurement in the coordinator)."""
        from .kernels.ref import kpd_reconstruct
        return tuple(kpd_reconstruct(params[f"{s.name}.S"],
                                     params[f"{s.name}.A"],
                                     params[f"{s.name}.B"])
                     for s in model.slots)

    info = {
        "method": "kpd", "rank": rank,
        "blocks": {k: list(v) for k, v in block_map.items()},
        "shapes": {k: dataclasses.asdict(v) for k, v in shapes.items()},
    }
    return MethodBundle(
        name="kpd", model=model, init=init, train_step=train_step,
        eval_step=_make_eval(model, lin),
        train_hyper=("lambda", "lr"),
        metric_names=("loss", "ce", "acc_count", "reg", "s_l1"),
        extras={"materialize": materialize}, info=info)


# ======================================================== dense baseline

def _dense_init(model: ModelDef, key, oinit):
    keys = jax.random.split(key, len(model.slots) + 1)
    params = dict(model.init_extra(keys[0]))
    for i, s in enumerate(model.slots):
        params.update(layers.dense_linear_init(keys[i + 1], s.name, s.m, s.n))
    return params, oinit(params)


def dense_method(model: ModelDef, optimizer: str = "sgd") -> MethodBundle:
    """Original uncompressed model (the Table-3 reference rows)."""
    oinit, oupd = _opt(optimizer)
    lin = layers.dense_linear_apply

    def init(key):
        return _dense_init(model, key, oinit)

    def loss_fn(params, x, y):
        ce, acc = _ce_and_count(model, lin, params, x, y)
        return ce, (ce, acc)

    def train_step(params, opt, x, y, lr):
        (total, (ce, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, x, y)
        params, opt = oupd(params, grads, opt, lr)
        return params, opt, jnp.stack([total, ce, acc])

    return MethodBundle(
        name="dense", model=model, init=init, train_step=train_step,
        eval_step=_make_eval(model, lin), train_hyper=("lr",),
        metric_names=("loss", "ce", "acc_count"), info={"method": "dense"})


# ==================================================== (elastic) group LASSO

def group_lasso_method(model: ModelDef, block_map: Dict[str, Tuple[int, int]],
                       elastic: bool = False, optimizer: str = "sgd"
                       ) -> MethodBundle:
    """(Elastic) group LASSO via **proximal** gradient descent: the CE
    gradient step is followed by the exact prox of λ1 Σ_g ‖W_g‖_F — the
    block-wise soft threshold W_g ← W_g · max(0, 1 − lr·λ1/‖W_g‖) — so
    losing blocks reach *exact* zeros (plain subgradient descent never
    does, which is why group-lasso implementations use prox or iterative
    thresholding; cf. Ida et al. 2019). The elastic variant adds the ℓ2
    prox W ← W / (1 + 2·lr·λ2)."""
    oinit, oupd = _opt(optimizer)
    lin = layers.dense_linear_apply
    blocks = {s.name: block_map[s.name] for s in model.slots}

    def init(key):
        return _dense_init(model, key, oinit)

    def loss_fn(params, x, y):
        ce, acc = _ce_and_count(model, lin, params, x, y)
        return ce, (ce, acc)

    def prox(params, lam1, lam2, lr):
        new = dict(params)
        for s in model.slots:
            m2, n2 = blocks[s.name]
            w = params[f"{s.name}.W"]
            m1, n1 = s.m // m2, s.n // n2
            wb = w.reshape(m1, m2, n1, n2)
            norms = jnp.sqrt((wb * wb).sum(axis=(1, 3), keepdims=True) + 1e-12)
            # canonical group-lasso weighting (Yuan & Lin): threshold scales
            # with sqrt(group size) so sparsity pressure is block-size-free
            thr = lr * lam1 * jnp.sqrt(jnp.float32(m2 * n2))
            scale = jnp.maximum(0.0, 1.0 - thr / norms)
            wb = wb * scale
            if elastic:
                wb = wb / (1.0 + 2.0 * lr * lam2)
            new[f"{s.name}.W"] = wb.reshape(s.m, s.n)
        return new

    def train_step(params, opt, x, y, lam1, lam2, lr):
        (total, (ce, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, x, y)
        params, opt = oupd(params, grads, opt, lr)
        params = prox(params, lam1, lam2, lr)
        reg = losses.group_lasso(params, blocks, lam1) if not elastic else \
            losses.elastic_group_lasso(params, blocks, lam1, lam2)
        return params, opt, jnp.stack([total, ce, acc, reg])

    name = "elastic_gl" if elastic else "group_lasso"
    return MethodBundle(
        name=name, model=model, init=init, train_step=train_step,
        eval_step=_make_eval(model, lin),
        train_hyper=("lambda1", "lambda2", "lr"),
        metric_names=("loss", "ce", "acc_count", "reg"),
        info={"method": name, "blocks": {k: list(v) for k, v in blocks.items()}})


# ======================================================== blockwise RigL

def rigl_method(model: ModelDef, block_map: Dict[str, Tuple[int, int]],
                density: float = 0.5, optimizer: str = "sgd") -> MethodBundle:
    """Blockwise RigL (paper §6.1's modification of Evci et al. 2020):
    drop by block-L1 of W, grow by block-L1 of the *dense* gradient.

    The train step consumes masked weights but differentiates w.r.t. the
    effective weights, so the metrics vector carries the dense-gradient
    block norms the coordinator feeds back into ``rigl_update``.
    """
    oinit, oupd = _opt(optimizer)
    blocks = {s.name: block_map[s.name] for s in model.slots}

    def lin(params, name, x):
        m2, n2 = blocks[name]
        return layers.masked_linear_apply(params, name, x, m2, n2)

    def init(key):
        keys = jax.random.split(key, len(model.slots) + 1)
        params = dict(model.init_extra(keys[0]))
        for i, s in enumerate(model.slots):
            m2, n2 = blocks[s.name]
            params.update(layers.masked_linear_init(
                keys[i + 1], s.name, s.m, s.n, m2, n2, density))
        return params, oinit(params)

    def split_eff(params):
        """Replace each slot's W with the effective (masked) weight, kept as
        a separate leaf so grad w.r.t. it is the DENSE RigL grow signal."""
        eff = {}
        rest = dict(params)
        for s in model.slots:
            w = rest.pop(f"{s.name}.W")
            mask = rest[f"{s.name}.mask"]
            m2, n2 = blocks[s.name]
            m1, n1 = s.m // m2, s.n // n2
            eff[f"{s.name}.W"] = (w.reshape(m1, m2, n1, n2)
                                  * mask[:, None, :, None]).reshape(s.m, s.n)
        return eff, rest

    def loss_fn(eff, rest, x, y):
        merged = dict(rest)
        merged.update(eff)
        ce, acc = _ce_and_count(model, layers.dense_linear_apply, merged, x, y)
        return ce, (ce, acc)

    def train_step(params, opt, x, y, lr):
        eff, rest = split_eff(params)
        (total, (ce, acc)), (g_eff, g_rest) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(eff, rest, x, y)
        # masked param update + dense-gradient block norms for grow
        grads = dict(g_rest)
        gnorms = []
        for s in model.slots:
            m2, n2 = blocks[s.name]
            m1, n1 = s.m // m2, s.n // n2
            ge = g_eff[f"{s.name}.W"]
            mask = params[f"{s.name}.mask"]
            grads[f"{s.name}.W"] = (ge.reshape(m1, m2, n1, n2)
                                    * mask[:, None, :, None]).reshape(s.m, s.n)
            gnorms.append(jnp.abs(ge.reshape(m1, m2, n1, n2)).sum(axis=(1, 3)).reshape(-1))
        params, opt = oupd(params, grads, opt, lr)
        metrics = jnp.concatenate([jnp.stack([total, ce, acc])] + gnorms)
        return params, opt, metrics

    def rigl_update(params, gnorm_flat, alpha):
        """Drop α of active blocks (smallest block-L1 of W), grow the same
        count by largest dense-grad block-L1 among inactive; grown blocks
        restart at 0 (RigL convention). nnz per slot is preserved."""
        new = dict(params)
        off = 0
        for s in model.slots:
            m2, n2 = blocks[s.name]
            m1, n1 = s.m // m2, s.n // n2
            nb = m1 * n1
            w = params[f"{s.name}.W"]
            mask = params[f"{s.name}.mask"].reshape(-1)
            gn = jax.lax.dynamic_slice(gnorm_flat, (off,), (nb,))
            off += nb
            mag = jnp.abs(w.reshape(m1, m2, n1, n2)).sum(axis=(1, 3)).reshape(-1)
            nnz = jnp.round(mask.sum()).astype(jnp.int32)
            k_drop = jnp.maximum(1, (alpha * nnz.astype(jnp.float32))).astype(jnp.int32)
            keep_n = nnz - k_drop
            neg_inf = jnp.float32(-1e30)
            mag_act = jnp.where(mask > 0, mag, neg_inf)
            # threshold for the blocks we keep
            sorted_mag = jnp.sort(mag_act)[::-1]
            keep_thr = sorted_mag[jnp.maximum(keep_n - 1, 0)]
            keep = (mag_act >= keep_thr) & (mask > 0)
            g_inact = jnp.where(mask > 0, neg_inf, gn)
            sorted_g = jnp.sort(g_inact)[::-1]
            grow_thr = sorted_g[jnp.maximum(k_drop - 1, 0)]
            grow = (g_inact >= grow_thr) & (mask <= 0)
            new_mask = (keep | grow).astype(jnp.float32).reshape(m1, n1)
            # zero-init grown blocks
            grown = grow.astype(jnp.float32).reshape(m1, n1)
            wz = w.reshape(m1, m2, n1, n2) * (1.0 - grown[:, None, :, None])
            new[f"{s.name}.W"] = wz.reshape(s.m, s.n)
            new[f"{s.name}.mask"] = new_mask
        return new

    gnorm_names = tuple(
        f"gnorm.{s.name}" for s in model.slots)
    return MethodBundle(
        name="rigl_block", model=model, init=init, train_step=train_step,
        eval_step=_make_eval(model, lin), train_hyper=("lr",),
        metric_names=("loss", "ce", "acc_count") + gnorm_names,
        extras={"rigl_update": rigl_update},
        info={"method": "rigl_block", "density": density,
              "blocks": {k: list(v) for k, v in blocks.items()},
              "gnorm_sizes": {s.name: (s.m // blocks[s.name][0])
                              * (s.n // blocks[s.name][1])
                              for s in model.slots}})


# =================================================== iterative pruning

def iter_prune_method(model: ModelDef, optimizer: str = "sgd") -> MethodBundle:
    """Unstructured iterative magnitude pruning (Han et al. 2015): dense
    training with an elementwise mask; the ``prune`` executable raises the
    sparsity to a target by zeroing the smallest-magnitude surviving
    weights; the coordinator alternates train and prune rounds."""
    oinit, oupd = _opt(optimizer)

    def lin(params, name, x):
        w = params[f"{name}.W"] * jax.lax.stop_gradient(params[f"{name}.emask"])
        y = x @ w.T
        b = params.get(f"{name}.bias")
        return y if b is None else y + b[None, :]

    def init(key):
        keys = jax.random.split(key, len(model.slots) + 1)
        params = dict(model.init_extra(keys[0]))
        for i, s in enumerate(model.slots):
            params.update(layers.dense_linear_init(keys[i + 1], s.name, s.m, s.n))
            params[f"{s.name}.emask"] = jnp.ones((s.m, s.n), jnp.float32)
        return params, oinit(params)

    def loss_fn(params, x, y):
        ce, acc = _ce_and_count(model, lin, params, x, y)
        return ce, (ce, acc)

    def train_step(params, opt, x, y, lr):
        (total, (ce, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, x, y)
        # mask the W grads so pruned weights stay dead
        for s in model.slots:
            grads[f"{s.name}.W"] = grads[f"{s.name}.W"] * params[f"{s.name}.emask"]
            grads[f"{s.name}.emask"] = jnp.zeros_like(params[f"{s.name}.emask"])
        params, opt = oupd(params, grads, opt, lr)
        return params, opt, jnp.stack([total, ce, acc])

    def prune(params, target_sparsity):
        """Zero the smallest |W| among surviving weights until the GLOBAL
        sparsity over all slots reaches the target."""
        new = dict(params)
        mags = []
        for s in model.slots:
            w = params[f"{s.name}.W"] * params[f"{s.name}.emask"]
            mags.append(jnp.abs(w).reshape(-1))
        allmag = jnp.concatenate(mags)
        n_total = allmag.shape[0]
        k_zero = (target_sparsity * n_total).astype(jnp.int32)
        thr = jnp.sort(allmag)[jnp.maximum(k_zero - 1, 0)]
        for s in model.slots:
            w = params[f"{s.name}.W"]
            keep = (jnp.abs(w * params[f"{s.name}.emask"]) > thr).astype(jnp.float32)
            new[f"{s.name}.emask"] = keep
            new[f"{s.name}.W"] = w * keep
        return new

    return MethodBundle(
        name="iter_prune", model=model, init=init, train_step=train_step,
        eval_step=_make_eval(model, lin), train_hyper=("lr",),
        metric_names=("loss", "ce", "acc_count"),
        extras={"prune": prune}, info={"method": "iter_prune"})


# ==================================================== pattern selection

def pattern_method(model: ModelDef,
                   patterns: Sequence[Dict[str, Tuple[int, int]]],
                   rank: int, optimizer: str = "sgd") -> MethodBundle:
    """Paper §5 / Eq. 7: K KPD candidates trained jointly; the backbone
    (convs/embeddings/norms/head) is shared across patterns, each pattern
    owns its slot factors under the ``p{k}.`` prefix. λ1 ramping drives the
    losing patterns' S to zero (Figure 3)."""
    oinit, oupd = _opt(optimizer)
    K = len(patterns)
    shapes: List[Dict[str, KPDShape]] = [
        {s.name: from_block(s.m, s.n, pat[s.name], rank) for s in model.slots}
        for pat in patterns
    ]

    def init(key):
        keys = jax.random.split(key, K * len(model.slots) + 1)
        params = dict(model.init_extra(keys[0]))
        idx = 1
        for k in range(K):
            for s in model.slots:
                params.update(layers.kpd_linear_init(
                    keys[idx], f"p{k}.{s.name}", shapes[k][s.name]))
                idx += 1
        return params, oinit(params)

    def lin_for(k):
        def lin(params, name, x):
            return layers.kpd_linear_apply(params, f"p{k}.{name}", x)
        return lin

    def loss_fn(params, x, y, lam1, lam2):
        total_ce = jnp.float32(0.0)
        accs = []
        for k in range(K):
            ce, acc = _ce_and_count(model, lin_for(k), params, x, y)
            total_ce = total_ce + ce
            accs.append(acc)
        reg = losses.pattern_penalty(params, K, lam1, lam2)
        return total_ce + reg, (total_ce, reg, accs)

    def train_step(params, opt, x, y, lam1, lam2, lr):
        (total, (ce, reg, accs)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, x, y, lam1, lam2)
        params, opt = oupd(params, grads, opt, lr)
        snorms = [losses.pattern_s_l1(params, k) for k in range(K)]
        metrics = jnp.stack([total, ce, reg] + accs + snorms)
        return params, opt, metrics

    def eval_step(params, x, y):
        """Per-pattern eval: [ce_k..., acc_k...]."""
        ces, accs = [], []
        for k in range(K):
            ce, acc = _ce_and_count(model, lin_for(k), params, x, y)
            ces.append(ce)
            accs.append(acc)
        return jnp.stack(ces + accs)

    metric_names = (("loss", "ce", "reg")
                    + tuple(f"acc_count_p{k}" for k in range(K))
                    + tuple(f"s_l1_p{k}" for k in range(K)))
    return MethodBundle(
        name=f"pattern{K}", model=model, init=init, train_step=train_step,
        eval_step=eval_step, train_hyper=("lambda1", "lambda2", "lr"),
        metric_names=metric_names,
        info={"method": "pattern", "rank": rank, "num_patterns": K,
              "patterns": [{k: list(v) for k, v in pat.items()}
                           for pat in patterns]})


def uniform_blocks(model: ModelDef, block: Tuple[int, int]) -> Dict[str, Tuple[int, int]]:
    """Same block size for every slot (the §6.3 transformer convention)."""
    return {s.name: block for s in model.slots}
