"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Everything here is written with the most literal einsum/kron formulation so
that it can be audited against the paper's equations directly. The Pallas
kernels in kpd_matmul.py / block_sparse.py must match these to float32
tolerance (pytest + hypothesis sweeps in python/tests/test_kernel.py).
"""

from __future__ import annotations

import jax.numpy as jnp


def kron(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Kronecker product of 2-D matrices: (m1,n1)⊗(m2,n2) → (m1·m2, n1·n2)."""
    m1, n1 = a.shape
    m2, n2 = b.shape
    return (a[:, None, :, None] * b[None, :, None, :]).reshape(m1 * m2, n1 * n2)


def kpd_reconstruct(s: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Materialize W_r = Σ_i (S ⊙ A_i) ⊗ B_i   (paper Eq. 3).

    s: (m1, n1); a: (r, m1, n1); b: (r, m2, n2) → (m1·m2, n1·n2)
    """
    r = a.shape[0]
    w = jnp.zeros((a.shape[1] * b.shape[1], a.shape[2] * b.shape[2]), a.dtype)
    for i in range(r):
        w = w + kron(s * a[i], b[i])
    return w


def kpd_forward_ref(x: jnp.ndarray, s: jnp.ndarray, a: jnp.ndarray,
                    b: jnp.ndarray) -> jnp.ndarray:
    """Reference KPD forward y = x @ W_rᵀ WITHOUT materializing W_r.

    Implements the Van Loan identity the paper uses in Appendix A.1.3:
        y_j = vec(Σ_i B_i · x̌_j · (S⊙A_i)ᵀ)
    with x̌_j[j2, j1] = x_j[j1·n2 + j2].

    x: (N, n1·n2) → (N, m1·m2).

    The einsum below is index-identical to the two-matmul schedule:
        y[N, i1·m2+i2] = Σ_i Σ_{j1 j2} (S⊙A_i)[i1,j1] · B_i[i2,j2] · x[N, j1·n2+j2]
    """
    r, m1, n1 = a.shape
    _, m2, n2 = b.shape
    xr = x.reshape(x.shape[0], n1, n2)
    sa = s[None] * a                                     # (r, m1, n1)
    y = jnp.einsum("rac,rbd,jcd->jab", sa, b, xr)        # (N, m1, m2)
    return y.reshape(x.shape[0], m1 * m2)


def kpd_forward_dense_ref(x: jnp.ndarray, s: jnp.ndarray, a: jnp.ndarray,
                          b: jnp.ndarray) -> jnp.ndarray:
    """Fully-materialized oracle-of-the-oracle: y = x @ W_rᵀ."""
    w = kpd_reconstruct(s, a, b)
    return x @ w.T


def block_sparse_matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
                            mask: jnp.ndarray) -> jnp.ndarray:
    """Inference-time block-sparse matmul oracle.

    w: (m1·m2, n1·n2) dense storage; mask: (m1, n1) {0,1} block mask.
    Zero blocks of w are masked out, then y = x @ (mask⊙W)ᵀ.
    """
    m1, n1 = mask.shape
    m, n = w.shape
    m2, n2 = m // m1, n // n1
    wm = w.reshape(m1, m2, n1, n2) * mask[:, None, :, None]
    return x @ wm.reshape(m, n).T


def block_l1_norms(w: jnp.ndarray, m2: int, n2: int) -> jnp.ndarray:
    """Per-block L1 norms of a dense matrix: (m1, n1) grid of Σ|w_block|.
    Used by the blockwise-RigL baseline's drop/grow criterion."""
    m, n = w.shape
    m1, n1 = m // m2, n // n2
    return jnp.abs(w.reshape(m1, m2, n1, n2)).sum(axis=(1, 3))


def block_fro_norms(w: jnp.ndarray, m2: int, n2: int) -> jnp.ndarray:
    """Per-block Frobenius norms (group-LASSO regularizer terms)."""
    m, n = w.shape
    m1, n1 = m // m2, n // n2
    sq = (w * w).reshape(m1, m2, n1, n2).sum(axis=(1, 3))
    return jnp.sqrt(sq)
