"""L1 Pallas kernel: the KPD forward hot-spot.

Computes, for a batch X ∈ R^{N×n} (n = n1·n2) and KPD factors
S ∈ R^{m1×n1}, A ∈ R^{r×m1×n1}, B ∈ R^{r×m2×n2}:

    Y = X @ W_rᵀ,   W_r = Σ_i (S ⊙ A_i) ⊗ B_i      (paper Eq. 3)

WITHOUT materializing W_r — the two-matmul Van Loan schedule of the paper's
Appendix A.1.3 (Eqs. 14–15):

    for each rank term i:
        T1 = reshape(X)ᵀ-view  @ B_iᵀ        # contract the n2 axis
        Y += reshape(T1)       @ (S⊙A_i)ᵀ    # contract the n1 axis

Hardware mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the batch
axis; each program keeps the *entire* factor set (S, A, B — a few KB) VMEM-
resident and streams one (TILE_N, n) slab of X HBM→VMEM. The rank loop is
unrolled inside the program so the accumulator never round-trips to HBM —
on a real TPU this is the VMEM scratch accumulator + MXU contraction; under
``interpret=True`` (mandatory on the CPU PJRT plugin) the same schedule runs
as numpy ops, which is what we validate against ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: multiple of 8 keeps the sublane dimension aligned on TPU;
# small enough that TILE_N×n and TILE_N×m slabs fit VMEM for every layer
# in this repo (worst case n=3072 → 128·3072·4B = 1.5 MiB per slab).
DEFAULT_TILE_N = 128


def _kpd_kernel(x_ref, s_ref, a_ref, b_ref, o_ref, *, r: int,
                m1: int, n1: int, m2: int, n2: int, tile_n: int):
    """One grid step: Y[tile] = Σ_i two-matmul(X[tile], S⊙A_i, B_i)."""
    x = x_ref[...]                                  # (tile_n, n1*n2)
    s = s_ref[...]                                  # (m1, n1)
    # (tile_n*n1, n2) view: row (j, j1) holds x[j, j1*n2 : (j1+1)*n2]
    xr = x.reshape(tile_n * n1, n2)
    acc = jnp.zeros((tile_n, m1 * m2), jnp.float32)
    for i in range(r):                              # fused rank loop (unrolled)
        sa = s * a_ref[i]                           # (m1, n1) elementwise mask
        bi = b_ref[i]                               # (m2, n2)
        # contract n2: T1[(j,j1), i2] = Σ_j2 x[j, j1*n2+j2] · B_i[i2, j2]
        t1 = jnp.dot(xr, bi.T, preferred_element_type=jnp.float32)
        # re-tile so n1 is the contracting axis:
        # T2[(j,i2), j1] = T1[(j,j1), i2]
        t2 = t1.reshape(tile_n, n1, m2).transpose(0, 2, 1).reshape(tile_n * m2, n1)
        # contract n1: T3[(j,i2), i1] = Σ_j1 T2 · (S⊙A_i)[i1, j1]
        t3 = jnp.dot(t2, sa.T, preferred_element_type=jnp.float32)
        # interleave back to y[j, i1*m2+i2]
        y = t3.reshape(tile_n, m2, m1).transpose(0, 2, 1).reshape(tile_n, m1 * m2)
        acc = acc + y
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tile_n",))
def kpd_forward(x: jnp.ndarray, s: jnp.ndarray, a: jnp.ndarray,
                b: jnp.ndarray, tile_n: int = DEFAULT_TILE_N) -> jnp.ndarray:
    """Pallas KPD forward. x: (N, n1·n2) → (N, m1·m2).

    Pads the batch to a tile multiple, launches a 1-D grid over batch
    tiles, and slices the padding back off. S/A/B are broadcast to every
    grid step (index_map pins them to block (0, …)) so they stay resident.
    """
    n_batch, n = x.shape
    r, m1, n1 = a.shape
    _, m2, n2 = b.shape
    assert n == n1 * n2, f"x feature dim {n} != n1*n2 = {n1 * n2}"
    m = m1 * m2

    tile = min(tile_n, max(8, n_batch))
    padded = ((n_batch + tile - 1) // tile) * tile
    if padded != n_batch:
        x = jnp.pad(x, ((0, padded - n_batch), (0, 0)))

    kernel = functools.partial(_kpd_kernel, r=r, m1=m1, n1=n1, m2=m2, n2=n2,
                               tile_n=tile)
    y = pl.pallas_call(
        kernel,
        grid=(padded // tile,),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),        # stream X
            pl.BlockSpec((m1, n1), lambda i: (0, 0)),         # resident S
            pl.BlockSpec((r, m1, n1), lambda i: (0, 0, 0)),   # resident A
            pl.BlockSpec((r, m2, n2), lambda i: (0, 0, 0)),   # resident B
        ],
        out_specs=pl.BlockSpec((tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, m), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, s, a, b)
    return y[:n_batch]


def kpd_forward_schedule(x: jnp.ndarray, s: jnp.ndarray, a: jnp.ndarray,
                         b: jnp.ndarray) -> jnp.ndarray:
    """The SAME two-matmul Van Loan schedule as `_kpd_kernel`, expressed as
    plain jnp ops (one whole-batch tile, rank loop unrolled).

    Why it exists (§Perf, EXPERIMENTS.md): `interpret=True` lowers
    pallas_call to a grid while-loop with dynamic-update-slices. The
    image's PJRT CPU backend (xla_extension 0.5.1, early-2023 XLA) does not
    fuse through that structure and runs it ~3× slower than the identical
    schedule written as straight-line HLO; modern jaxlib shows no such gap.
    Artifacts are exported with this fast path by default
    (BS_KPD_IMPL=pallas opts back in); the pallas kernel remains the TPU
    lowering target and the correctness reference for both (pytest checks
    kernel == schedule == oracle).
    """
    n_batch, n = x.shape
    r, m1, n1 = a.shape
    _, m2, n2 = b.shape
    xr = x.reshape(n_batch * n1, n2)
    acc = jnp.zeros((n_batch, m1 * m2), jnp.float32)
    for i in range(r):
        sa = s * a[i]
        t1 = jnp.dot(xr, b[i].T, preferred_element_type=jnp.float32)
        t2 = t1.reshape(n_batch, n1, m2).transpose(0, 2, 1).reshape(n_batch * m2, n1)
        t3 = jnp.dot(t2, sa.T, preferred_element_type=jnp.float32)
        acc = acc + t3.reshape(n_batch, m2, m1).transpose(0, 2, 1).reshape(
            n_batch, m1 * m2)
    return acc


def kpd_forward_vmem_bytes(n_batch: int, r: int, m1: int, n1: int,
                           m2: int, n2: int, tile_n: int = DEFAULT_TILE_N,
                           bytes_per_el: int = 4) -> int:
    """Static VMEM footprint estimate of one grid step (perf model, used by
    DESIGN/EXPERIMENTS §Perf — interpret-mode wallclock is NOT a TPU proxy).

    Slabs resident per step: X tile, S, A, B, the two matmul temporaries,
    and the accumulator/output tile.
    """
    tile = min(tile_n, max(8, n_batch))
    n, m = n1 * n2, m1 * m2
    x_tile = tile * n
    factors = m1 * n1 + r * (m1 * n1 + m2 * n2)
    t1 = tile * n1 * m2
    t2 = tile * m2 * n1
    acc_out = 2 * tile * m
    return (x_tile + factors + t1 + t2 + acc_out) * bytes_per_el


def kpd_forward_mxu_flops(n_batch: int, r: int, m1: int, n1: int,
                          m2: int, n2: int) -> int:
    """MXU (matmul) flops of the schedule: 2·N·r·(n1·n2·m2 + m2·n1·m1).
    Matches the paper's Eq. 16 leading terms."""
    return 2 * n_batch * r * (n1 * n2 * m2 + m2 * n1 * m1)
