"""L1 Pallas kernel: inference-time block-sparse matmul.

After training, the paper materializes the block-wise sparse matrix
W = Σ_i (S⊙A_i) ⊗ B_i and serves it directly (§4: "During inference, our
algorithm directly uses block-wise sparse matrices"). The zero pattern is
given by the (m1, n1) block mask derived from S.

The kernel grid is (batch tiles × output-block rows). Each program owns one
(TILE_N, m2) output slab and walks the n1 block columns; blocks whose mask
entry is zero contribute nothing. On a real TPU the mask lives in SMEM and
zero blocks are *skipped* (no HBM fetch of the weight block, no MXU pass) —
the array-datapath win the paper's §2 "Block-wise Sparsity" paragraph
describes. Under interpret=True we realize the same dataflow with a masked
accumulate, which is numerically identical; the skip is modeled in the perf
estimator below.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 128


def _bs_kernel(x_ref, w_ref, mask_ref, o_ref, *, n1: int, m2: int, n2: int,
               tile_n: int):
    """One grid step: out (tile_n, m2) for output block-row i1 = pid(1)."""
    i1 = pl.program_id(1)
    x = x_ref[...]                          # (tile_n, n1*n2)
    wrow = w_ref[...]                       # (m2, n1*n2): block-row i1 of W
    mask = mask_ref[...]                    # (1, n1): mask row i1
    acc = jnp.zeros((tile_n, m2), jnp.float32)
    for j1 in range(n1):                    # walk block columns (unrolled)
        mv = mask[0, j1]
        xb = x[:, j1 * n2:(j1 + 1) * n2]            # (tile_n, n2)
        wb = wrow[:, j1 * n2:(j1 + 1) * n2]         # (m2, n2)
        # masked accumulate == skip on real HW (mv ∈ {0,1})
        acc = acc + mv * jnp.dot(xb, wb.T, preferred_element_type=jnp.float32)
    del i1
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("m1", "tile_n"))
def block_sparse_matmul(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray,
                        m1: int, tile_n: int = DEFAULT_TILE_N) -> jnp.ndarray:
    """y = x @ (mask ⊙_block W)ᵀ with block-level sparsity.

    x: (N, n); w: (m, n) dense storage with m = m1·m2; mask: (m1, n1) {0,1}.
    """
    n_batch, n = x.shape
    m, n_w = w.shape
    assert n == n_w
    m1_, n1 = mask.shape
    assert m1_ == m1 and m % m1 == 0 and n % n1 == 0
    m2, n2 = m // m1, n // n1

    tile = min(tile_n, max(8, n_batch))
    padded = ((n_batch + tile - 1) // tile) * tile
    if padded != n_batch:
        x = jnp.pad(x, ((0, padded - n_batch), (0, 0)))

    kernel = functools.partial(_bs_kernel, n1=n1, m2=m2, n2=n2, tile_n=tile)
    y = pl.pallas_call(
        kernel,
        grid=(padded // tile, m1),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i, j: (i, 0)),   # X batch tile
            pl.BlockSpec((m2, n), lambda i, j: (j, 0)),     # W block-row j
            pl.BlockSpec((1, n1), lambda i, j: (j, 0)),     # mask row j
        ],
        out_specs=pl.BlockSpec((tile, m2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((padded, m), jnp.float32),
        interpret=True,
    )(x, w, mask)
    return y[:n_batch]


def block_sparse_flops(n_batch: int, m1: int, n1: int, m2: int, n2: int,
                       nnz_blocks: int) -> int:
    """Effective matmul flops with zero blocks skipped: 2·N·m2·n2·nnz."""
    return 2 * n_batch * m2 * n2 * nnz_blocks


def block_sparse_dense_flops(n_batch: int, m: int, n: int) -> int:
    """Dense equivalent for the speedup ratio in the benches."""
    return 2 * n_batch * m * n
