"""L2 layer library: KPD-factorized linear layers and dense companions.

The KPD layer is the paper's Eq. 3 parameterization. Its forward runs the
L1 Pallas kernel (kernels/kpd_matmul.py); its backward is a ``custom_vjp``
implementing the paper's Appendix A.1.4 gradient schedule (Eqs. 19-24)
explicitly — pallas_call has no reverse-mode rule, and writing the backward
by hand keeps the lowered HLO's FLOP structure identical to the paper's
Proposition 2 accounting.

Parameter trees are flat ``dict[str, jnp.ndarray]`` with dotted names; the
AOT manifest sorts keys to fix the PJRT argument order.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

import os

from .kernels.kpd_matmul import kpd_forward as _pallas_kpd_forward
from .kernels.kpd_matmul import kpd_forward_schedule as _schedule_kpd_forward
from .kernels.ref import kpd_forward_ref
from .shapes import KPDShape

Params = Dict[str, jnp.ndarray]

# Forward implementation selector (§Perf): "schedule" (default) exports the
# kernel's exact two-matmul schedule as straight-line HLO — the interpret-
# mode pallas while-loop compiles ~3× slower on the image's 2023-era PJRT
# CPU backend. "pallas" opts into the pallas_call lowering (the TPU path).
# Both are verified identical against ref.py by pytest.
_KPD_IMPL = os.environ.get("BS_KPD_IMPL", "schedule")


def _kpd_forward_impl(x, s, a, b):
    if _KPD_IMPL == "pallas":
        return _pallas_kpd_forward(x, s, a, b)
    return _schedule_kpd_forward(x, s, a, b)


# --------------------------------------------------------------------------
# KPD forward/backward with custom VJP
# --------------------------------------------------------------------------

@jax.custom_vjp
def kpd_apply(x: jnp.ndarray, s: jnp.ndarray, a: jnp.ndarray,
              b: jnp.ndarray) -> jnp.ndarray:
    """y = x @ W_rᵀ with W_r = Σ_i (S⊙A_i)⊗B_i, never materialized."""
    return _kpd_forward_impl(x, s, a, b)


def _kpd_fwd(x, s, a, b):
    return _kpd_forward_impl(x, s, a, b), (x, s, a, b)


def _kpd_bwd(res, g):
    """Paper Appendix A.1.4: gradients w.r.t. S, A_i, B_i and the input.

    With y[j, i1·m2+i2] = Σ_i (S⊙A_i)[i1,j1]·B_i[i2,j2]·x[j, j1·n2+j2]:
      ∂J/∂(S⊙A_i)  = Eq. 20   (contract batch & block axes)
      ∂J/∂S        = Σ_i Eq.20 ⊙ A_i              (Eq. 21)
      ∂J/∂A_i      = Eq.20 ⊙ S                    (Eq. 22)
      ∂J/∂B_i      = Eq. 24
      ∂J/∂x        = transpose pass (needed for multi-layer chains, Eq. 51)
    """
    x, s, a, b = res
    r, m1, n1 = a.shape
    _, m2, n2 = b.shape
    nb = x.shape[0]
    gr = g.reshape(nb, m1, m2)
    xr = x.reshape(nb, n1, n2)
    sa = s[None] * a
    # Eq. 20: dJ/d(S⊙A_i)[a,c] = Σ_{j,b,d} g[j,a,b]·B_i[b,d]·x̌[j,c,d]
    d_sa = jnp.einsum("jab,ibd,jcd->iac", gr, b, xr)
    d_s = (d_sa * a).sum(axis=0)                     # Eq. 21
    d_a = d_sa * s[None]                             # Eq. 22
    # Eq. 24: dJ/dB_i[b,d] = Σ_{j,a,c} g[j,a,b]·(S⊙A_i)[a,c]·x̌[j,c,d]
    d_b = jnp.einsum("jab,iac,jcd->ibd", gr, sa, xr)
    # Eq. 51 analogue: dJ/dx̌[j,c,d] = Σ_{i,a,b} g[j,a,b]·(S⊙A_i)[a,c]·B_i[b,d]
    d_x = jnp.einsum("jab,iac,ibd->jcd", gr, sa, b).reshape(nb, n1 * n2)
    return d_x, d_s, d_a, d_b


kpd_apply.defvjp(_kpd_fwd, _kpd_bwd)


def kpd_apply_ref(x, s, a, b):
    """Pure-jnp twin of kpd_apply (autodiff-able end to end); used by the
    parity tests to check the custom VJP against jax's own gradients."""
    return kpd_forward_ref(x, s, a, b)


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------

def glorot(key, shape, fan_in: int, fan_out: int) -> jnp.ndarray:
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def kpd_init(key, shape: KPDShape) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Init (S, A, B) so the *effective* W_r has dense-glorot-like scale.

    Var(W) target = 2/(m+n). Each rank term is a product S·A·B of three
    independent factors; with r terms summed, set each factor's std to
    (target_var / r)^{1/6} … S starts at 1.0 (no sparsity prior) and A, B
    split the scale evenly, matching the preliminary-code convention.
    """
    ka, kb = jax.random.split(key)
    target_std = jnp.sqrt(2.0 / (shape.m + shape.n))
    per_factor = (target_std / jnp.sqrt(shape.r)) ** 0.5
    s = jnp.ones((shape.m1, shape.n1), jnp.float32)
    a = jax.random.normal(ka, (shape.r, shape.m1, shape.n1), jnp.float32) * per_factor
    b = jax.random.normal(kb, (shape.r, shape.m2, shape.n2), jnp.float32) * per_factor
    return s, a, b


# --------------------------------------------------------------------------
# Layer constructors: each returns (params: dict, apply closure metadata)
# --------------------------------------------------------------------------

def kpd_linear_init(key, name: str, shape: KPDShape, bias: bool = True) -> Params:
    s, a, b = kpd_init(key, shape)
    p = {f"{name}.S": s, f"{name}.A": a, f"{name}.B": b}
    if bias:
        p[f"{name}.bias"] = jnp.zeros((shape.m,), jnp.float32)
    return p


def kpd_linear_apply(params: Params, name: str, x: jnp.ndarray) -> jnp.ndarray:
    y = kpd_apply(x, params[f"{name}.S"], params[f"{name}.A"], params[f"{name}.B"])
    bkey = f"{name}.bias"
    if bkey in params:
        y = y + params[bkey][None, :]
    return y


def dense_linear_init(key, name: str, m: int, n: int, bias: bool = True) -> Params:
    p = {f"{name}.W": glorot(key, (m, n), n, m)}
    if bias:
        p[f"{name}.bias"] = jnp.zeros((m,), jnp.float32)
    return p


def dense_linear_apply(params: Params, name: str, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params[f"{name}.W"].T
    bkey = f"{name}.bias"
    if bkey in params:
        y = y + params[bkey][None, :]
    return y


def masked_linear_init(key, name: str, m: int, n: int, m2: int, n2: int,
                       density: float, bias: bool = True) -> Params:
    """Dense weight + frozen block mask — the blockwise-RigL baseline's
    parameterization. The mask is a parameter (so it rides through the AOT
    signature) but is updated only by the rigl_update executable."""
    kw, km = jax.random.split(key)
    m1, n1 = m // m2, n // n2
    p = {f"{name}.W": glorot(kw, (m, n), n, m)}
    nnz = max(1, int(round(density * m1 * n1)))
    flat = jnp.zeros((m1 * n1,), jnp.float32).at[
        jax.random.permutation(km, m1 * n1)[:nnz]].set(1.0)
    p[f"{name}.mask"] = flat.reshape(m1, n1)
    if bias:
        p[f"{name}.bias"] = jnp.zeros((m,), jnp.float32)
    return p


def masked_linear_apply(params: Params, name: str, x: jnp.ndarray,
                        m2: int, n2: int) -> jnp.ndarray:
    w = params[f"{name}.W"]
    mask = jax.lax.stop_gradient(params[f"{name}.mask"])
    m, n = w.shape
    m1, n1 = m // m2, n // n2
    wm = (w.reshape(m1, m2, n1, n2) * mask[:, None, :, None]).reshape(m, n)
    y = x @ wm.T
    bkey = f"{name}.bias"
    if bkey in params:
        y = y + params[bkey][None, :]
    return y


def layer_norm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
