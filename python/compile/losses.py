"""Loss functions and the paper's regularizers.

* cross_entropy          — softmax CE with integer labels (all experiments)
* kpd_l1                 — λ Σ_l ‖S^[l]‖₁                     (paper Eq. 4)
* group_lasso            — λ Σ_l Σ_g ‖W_g^[l]‖_F              (paper Eq. 1)
* elastic_group_lasso    — group lasso + ℓ2 term (Oyedotun et al. 2020)
* pattern_penalty        — λ1 Σ_k sqrt(Σ_l ‖S^{(k)}‖_F²) + λ2 Σ_{k,l} ‖S^{(k)}‖₁
                                                               (paper Eq. 7)
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32),
                                 axis=-1)[:, 0]
    return (logz - picked).mean()


def accuracy_count(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Number of correct predictions (f32 so it flows through PJRT easily)."""
    return (jnp.argmax(logits, axis=-1) == labels.astype(jnp.int32)).sum().astype(jnp.float32)


def kpd_l1(params: Params, lam: jnp.ndarray) -> jnp.ndarray:
    """λ Σ ‖S‖₁ over every KPD layer (keys ending in '.S')."""
    total = jnp.float32(0.0)
    for k in sorted(params):
        if k.endswith(".S"):
            total = total + jnp.abs(params[k]).sum()
    return lam * total


def _block_fro_sum(w: jnp.ndarray, m2: int, n2: int) -> jnp.ndarray:
    m, n = w.shape
    m1, n1 = m // m2, n // n2
    sq = (w * w).reshape(m1, m2, n1, n2).sum(axis=(1, 3))
    # smooth sqrt at 0: the subgradient of ‖·‖_F at 0 is handled by +eps,
    # standard practice for group-lasso SGD training.
    return jnp.sqrt(sq + 1e-12).sum()


def group_lasso(params: Params, blocks: Dict[str, Tuple[int, int]],
                lam: jnp.ndarray) -> jnp.ndarray:
    """λ Σ_l Σ_g ‖W_g‖_F with per-layer block sizes (Eq. 1)."""
    total = jnp.float32(0.0)
    for name, (m2, n2) in sorted(blocks.items()):
        total = total + _block_fro_sum(params[f"{name}.W"], m2, n2)
    return lam * total


def elastic_group_lasso(params: Params, blocks: Dict[str, Tuple[int, int]],
                        lam1: jnp.ndarray, lam2: jnp.ndarray) -> jnp.ndarray:
    """Elastic variant: group term + ridge term on the grouped weights."""
    total = group_lasso(params, blocks, lam1)
    for name in sorted(blocks):
        w = params[f"{name}.W"]
        total = total + lam2 * (w * w).sum()
    return total


def pattern_s_l1(params: Params, k: int) -> jnp.ndarray:
    """Σ_l ‖S^{(k),[l]}‖₁ — the Figure-3 diagnostic series."""
    total = jnp.float32(0.0)
    prefix = f"p{k}."
    for key in sorted(params):
        if key.startswith(prefix) and key.endswith(".S"):
            total = total + jnp.abs(params[key]).sum()
    return total


def pattern_penalty(params: Params, num_patterns: int,
                    lam1: jnp.ndarray, lam2: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 7 regularizer over K pattern candidates.

    Pattern k's parameters carry the name prefix ``p{k}.``. The sqrt-of-
    Frobenius term acts as group lasso *across patterns*: losing patterns
    are driven to exactly zero as λ1 ramps.
    """
    total = jnp.float32(0.0)
    for k in range(num_patterns):
        prefix = f"p{k}."
        fro = jnp.float32(0.0)
        l1 = jnp.float32(0.0)
        for key in sorted(params):
            if key.startswith(prefix) and key.endswith(".S"):
                s = params[key]
                fro = fro + (s * s).sum()
                l1 = l1 + jnp.abs(s).sum()
        total = total + lam1 * jnp.sqrt(fro + 1e-12) + lam2 * l1
    return total
