"""AOT compiler: lower every experiment spec to HLO **text** + manifest.

This is the only python entry point in the build (`make artifacts`); the
rust coordinator never imports python. For each Spec we lower up to five
executables:

  init        (seed:u32)                        -> (params, opt)
  train_step  (params, opt, x, y, *hyper:f32)   -> (params, opt, metrics)
  eval_step   (params, x, y)                    -> metrics
  materialize (params)                          -> per-slot W  [kpd only]
  rigl_update (params, gnorm:f32[*], alpha:f32) -> params      [rigl only]
  prune       (params, target:f32)              -> params      [iter_prune]

Interchange is HLO *text*: the image's xla_extension 0.5.1 rejects jax>=0.5
serialized HloModuleProtos (64-bit instruction ids); the text parser
reassigns ids (see /opt/xla-example/README.md). Argument order is the
pytree flatten order of the example arguments — dicts flatten in sorted-key
order, which the manifest records explicitly so the rust runtime never has
to re-derive it.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .models import MODELS
from .specs import Spec, build_specs

DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32",
               jnp.uint32.dtype: "u32"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _leaf_meta(x) -> Dict:
    shape = list(jnp.shape(x))
    dtype = x.dtype if hasattr(x, "dtype") else jnp.asarray(x).dtype
    return {"shape": shape, "dtype": DTYPE_NAMES[dtype]}


def _abstract(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(
            jnp.shape(x), x.dtype if hasattr(x, "dtype") else jnp.asarray(x).dtype),
        tree)


def _named_leaves(prefix: str, d: Dict) -> List[Tuple[str, object]]:
    """Sorted-key order == jax dict flatten order; keep them in lockstep."""
    return [(f"{prefix}:{k}", d[k]) for k in sorted(d)]


class Emitter:
    def __init__(self, out_dir: str, skip_existing: bool = False):
        self.out_dir = out_dir
        self.skip = skip_existing
        self.entries: List[Dict] = []

    def emit(self, spec_key: str, exec_name: str, fn, example_args,
             input_names: List[Tuple[str, object]],
             output_names: List[Tuple[str, object]], extra: Dict) -> None:
        fname = f"{spec_key}.{exec_name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        if not (self.skip and os.path.exists(path)):
            t0 = time.time()
            # keep_unused: the manifest promises the full argument list even
            # for executables that ignore some leaves (e.g. materialize
            # ignores biases) — argument order must stay stable.
            lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            print(f"  {fname}: {len(text) / 1e6:.2f} MB in {time.time() - t0:.1f}s",
                  flush=True)
        self.entries.append({
            "spec": spec_key,
            "exec": exec_name,
            "file": fname,
            "inputs": [{"name": n, **_leaf_meta(v)} for n, v in input_names],
            "outputs": [{"name": n, **_leaf_meta(v)} for n, v in output_names],
            **extra,
        })


def lower_spec(spec: Spec, em: Emitter) -> Dict:
    model = MODELS[spec.model_name]()
    bundle = spec.build(model)
    key0 = jax.random.PRNGKey(0)
    params, opt = bundle.init(key0)
    n = spec.batch

    if model.input_dtype == "i32":
        x_ex = jnp.zeros((n,) + model.input_shape, jnp.int32)
        y_ex = jnp.zeros((n,) + model.input_shape, jnp.int32)   # LM targets
    else:
        x_ex = jnp.zeros((n,) + model.input_shape, jnp.float32)
        y_ex = jnp.zeros((n,), jnp.int32)
    hyper_ex = [jnp.float32(0.0) for _ in bundle.train_hyper]

    p_named = _named_leaves("param", params)
    o_named = _named_leaves("opt", opt)

    # ---- init ----
    def init_from_seed(seed):
        return bundle.init(jax.random.PRNGKey(seed))

    em.emit(spec.key, "init", init_from_seed,
            (jax.ShapeDtypeStruct((), jnp.uint32),),
            [("seed", jnp.uint32(0))], p_named + o_named, {})

    # ---- train_step ----
    new_p, new_o, metrics = jax.eval_shape(
        bundle.train_step, _abstract(params), _abstract(opt),
        _abstract(x_ex), _abstract(y_ex), *hyper_ex)
    em.emit(spec.key, "train_step", bundle.train_step,
            (_abstract(params), _abstract(opt), _abstract(x_ex),
             _abstract(y_ex)) + tuple(hyper_ex),
            p_named + o_named + [("x", x_ex), ("y", y_ex)]
            + [(h, jnp.float32(0.0)) for h in bundle.train_hyper],
            _named_leaves("param", new_p) + _named_leaves("opt", new_o)
            + [("metrics", metrics)],
            {"hyper": list(bundle.train_hyper),
             "metrics": list(bundle.metric_names)})

    # ---- eval_step ----
    ev = jax.eval_shape(bundle.eval_step, _abstract(params),
                        _abstract(x_ex), _abstract(y_ex))
    em.emit(spec.key, "eval_step", bundle.eval_step,
            (_abstract(params), _abstract(x_ex), _abstract(y_ex)),
            p_named + [("x", x_ex), ("y", y_ex)], [("metrics", ev)], {})

    # ---- extras ----
    for ename, efn in bundle.extras.items():
        if ename == "materialize":
            outs = jax.eval_shape(efn, _abstract(params))
            em.emit(spec.key, ename, efn, (_abstract(params),), p_named,
                    [(f"W:{s.name}", w) for s, w in zip(model.slots, outs)], {})
        elif ename == "rigl_update":
            gsizes = bundle.info["gnorm_sizes"]
            gtot = sum(gsizes[s.name] for s in model.slots)
            g_ex = jnp.zeros((gtot,), jnp.float32)
            outp = jax.eval_shape(efn, _abstract(params), _abstract(g_ex),
                                  jnp.float32(0.3))
            em.emit(spec.key, ename, efn,
                    (_abstract(params), _abstract(g_ex), jnp.float32(0.0)),
                    p_named + [("gnorm", g_ex), ("alpha", jnp.float32(0.3))],
                    _named_leaves("param", outp), {})
        elif ename == "prune":
            outp = jax.eval_shape(efn, _abstract(params), jnp.float32(0.5))
            em.emit(spec.key, ename, efn,
                    (_abstract(params), jnp.float32(0.0)),
                    p_named + [("target", jnp.float32(0.5))],
                    _named_leaves("param", outp), {})
        else:
            raise ValueError(f"unknown extra {ename}")

    return {
        "key": spec.key,
        "model": spec.model_name,
        "batch": spec.batch,
        "tags": list(spec.tags),
        "input_shape": list(model.input_shape),
        "input_dtype": model.input_dtype,
        "num_classes": model.num_classes,
        "slots": [{"name": s.name, "m": s.m, "n": s.n} for s in model.slots],
        "method": bundle.name,
        "hyper": list(bundle.train_hyper),
        "metrics": list(bundle.metric_names),
        "info": bundle.info,
        # trainable parameters only: masks (RigL) and emasks (pruning) are
        # frozen bookkeeping, not trained — the paper's "Training Params"
        # column counts what gradient descent updates.
        "params_total": int(sum(
            int(jnp.asarray(v).size) for k, v in params.items()
            if not (k.endswith(".mask") or k.endswith(".emask")))),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output dir (a path ending in .txt means its dir)")
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--only", default=None, help="regex over spec keys")
    ap.add_argument("--tag", default=None, help="only specs carrying this tag")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = args.out_dir
    if out_dir is None:
        out_dir = os.path.dirname(args.out) if args.out.endswith(".txt") else args.out
    os.makedirs(out_dir, exist_ok=True)

    specs = build_specs()
    if args.only:
        rx = re.compile(args.only)
        specs = [s for s in specs if rx.search(s.key)]
    if args.tag:
        specs = [s for s in specs if args.tag in s.tags]
    if args.list:
        for s in specs:
            print(f"{s.key:30s} model={s.model_name:12s} batch={s.batch} "
                  f"tags={','.join(s.tags)}")
        return

    em = Emitter(out_dir, skip_existing=args.skip_existing)
    spec_meta = []
    t0 = time.time()
    for s in specs:
        print(f"[{s.key}] lowering (model={s.model_name}, batch={s.batch})",
              flush=True)
        spec_meta.append(lower_spec(s, em))

    manifest = {
        "version": 1,
        "generated_by": "python/compile/aot.py",
        "jax_version": jax.__version__,
        "specs": spec_meta,
        "executables": em.entries,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    # merge with an existing manifest when building a subset
    if (args.only or args.tag) and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        keep = {s["key"] for s in spec_meta}
        manifest["specs"] = [s for s in old.get("specs", [])
                             if s["key"] not in keep] + spec_meta
        manifest["executables"] = [e for e in old.get("executables", [])
                                   if e["spec"] not in keep] + em.entries
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}: {len(manifest['executables'])} executables "
          f"({time.time() - t0:.0f}s total)")


if __name__ == "__main__":
    main()
