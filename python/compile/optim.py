"""Minimal optimizers for the AOT train steps.

Implemented from scratch (no optax in the image) over flat name→array
parameter dicts. Optimizer state is itself a flat dict so the whole
(params, state) bundle flattens into a deterministic PJRT argument list.

Frozen parameters: any key whose leaf name is in FROZEN_LEAVES (e.g. RigL
masks) receives no update and carries no state.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]

FROZEN_LEAVES = ("mask",)


def is_frozen(key: str) -> bool:
    return key.rsplit(".", 1)[-1] in FROZEN_LEAVES


# ---------------------------------------------------------------- SGD(+mom)

def sgd_init(params: Params) -> Params:
    return {f"mom.{k}": jnp.zeros_like(v) for k, v in params.items()
            if not is_frozen(k)}


def sgd_update(params: Params, grads: Params, state: Params,
               lr: jnp.ndarray, momentum: float = 0.9
               ) -> Tuple[Params, Params]:
    new_p, new_s = {}, {}
    for k in sorted(params):
        if is_frozen(k):
            new_p[k] = params[k]
            continue
        m = momentum * state[f"mom.{k}"] + grads[k]
        new_s[f"mom.{k}"] = m
        new_p[k] = params[k] - lr * m
    return new_p, new_s


# -------------------------------------------------------------------- Adam

def adam_init(params: Params) -> Params:
    state: Params = {"t": jnp.zeros((), jnp.float32)}
    for k, v in params.items():
        if is_frozen(k):
            continue
        state[f"m.{k}"] = jnp.zeros_like(v)
        state[f"v.{k}"] = jnp.zeros_like(v)
    return state


def adam_update(params: Params, grads: Params, state: Params,
                lr: jnp.ndarray, b1: float = 0.9, b2: float = 0.999,
                eps: float = 1e-8) -> Tuple[Params, Params]:
    t = state["t"] + 1.0
    new_p, new_s = {}, {"t": t}
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    for k in sorted(params):
        if is_frozen(k):
            new_p[k] = params[k]
            continue
        g = grads[k]
        m = b1 * state[f"m.{k}"] + (1.0 - b1) * g
        v = b2 * state[f"v.{k}"] + (1.0 - b2) * (g * g)
        new_s[f"m.{k}"] = m
        new_s[f"v.{k}"] = v
        mh = m / bc1
        vh = v / bc2
        new_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
    return new_p, new_s


OPTIMIZERS = {
    "sgd": (sgd_init, sgd_update),
    "adam": (adam_init, adam_update),
}
